"""Compressed-aggregation routing contract (the int8 packed path).

With ``compression: int8`` the drivers must aggregate through
``kernels/ops.quant_aggregate`` — asserted via the dispatcher's trace-time
counters, not code inspection — and the trajectory must be bitwise
identical between the fused path and the dequant-first reference
(``REPRO_QUANT_AGG=dequant``), in every driver: sync spatial, sync
temporal, async FedAsync (per-event) and async FedBuff (buffer flushes).
Chunking invariance must survive the packed buffers FedBuff carries in its
event-scan state.
"""
import os

os.environ.setdefault("REPRO_KERNEL_IMPL", "jnp")

import jax
import numpy as np
import pytest

from repro.core.jobs import load_job
from repro.kernels import ops
from repro.runtime.executor import Executor


def _job(rounds_per_launch: int = 2, rounds: int = 4, seed: int = 7, *,
         mode: str = "sync", placement: str = "spatial",
         async_buffer: int = 0, runtime=None, **train_extra):
    tp = {"n_clients": 4, "local_epochs": 1, "client_lr": 0.1,
          "rounds": rounds, "seed": seed, "mode": mode,
          "placement": placement, "rounds_per_launch": rounds_per_launch,
          "compression": "int8", "error_feedback": True}
    if mode == "async":
        tp.update({"async_buffer": async_buffer, "max_staleness": 4,
                   "staleness_exponent": 0.5})
        runtime = runtime or {"straggler_prob": 0.2, "duration_sigma": 0.25}
    tp.update(train_extra)
    return load_job({
        "name": f"quant-agg-{mode}-{placement}",
        "model": {"arch": "flsim-mlp"},
        "dataset": {"dataset": "synthetic_vision", "n_items": 256,
                    "distribution": {"partition": "dirichlet",
                                     "dirichlet_alpha": 0.5}},
        "strategy": {"strategy": "compressed", "train_params": tp},
        "runtime": runtime or {"straggler_prob": 0.2,
                               "straggler_overprovision": 1.25},
    })


def _params(state):
    return jax.tree.map(np.asarray, state["params"])


def _assert_bitwise_equal(p1, p2):
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(a, b)


# one driver config per compiled aggregation site
DRIVERS = {
    "sync-spatial": dict(mode="sync", placement="spatial"),
    "sync-temporal": dict(mode="sync", placement="temporal"),
    "async-fedasync": dict(mode="async", async_buffer=0),
    "async-fedbuff": dict(mode="async", async_buffer=3),
}


@pytest.mark.parametrize("driver", sorted(DRIVERS))
def test_int8_routes_through_quant_aggregate(driver, monkeypatch):
    """The dispatcher's trace-time counter must tick when the compressed
    driver compiles — proof the packed path is the one executing."""
    monkeypatch.delenv("REPRO_QUANT_AGG", raising=False)
    jax.clear_caches()                 # force a fresh trace per driver
    ops.reset_quant_agg_stats()
    ex = Executor(_job(**DRIVERS[driver])).scaffold()
    _, logger = ex.run()
    stats = ops.quant_agg_stats()
    assert stats["calls"] > 0, f"{driver}: aggregation bypassed the kernel"
    assert stats["last_impl"] == "jnp-fused"
    losses = logger.series("loss")
    assert losses[-1] < losses[0], f"{driver}: compressed run not learning"


@pytest.mark.parametrize("driver", sorted(DRIVERS))
def test_fused_equals_dequant_first_trajectory(driver, monkeypatch):
    """End-to-end bitwise contract: the whole trajectory (quantize ->
    aggregate -> server update, every round) agrees between the fused
    kernel path and the dequant-first reference."""
    runs = {}
    for quant_mode in ("fused", "dequant"):
        monkeypatch.setenv("REPRO_QUANT_AGG", quant_mode)
        jax.clear_caches()             # env is read at trace time
        state, _ = Executor(_job(**DRIVERS[driver])).scaffold().run()
        runs[quant_mode] = _params(state)
    _assert_bitwise_equal(runs["fused"], runs["dequant"])


@pytest.mark.parametrize("async_buffer", [3, 0])
def test_packed_async_chunked_equals_unchunked(async_buffer, monkeypatch):
    """FedBuff carries packed (K, N) int8 buffers in the event-scan state;
    chunk boundaries must not perturb them. availability < 1 mixes
    rejected arrivals in, so the accept-gated slot writes are exercised
    (a rejected event must neither fill a slot nor advance the count)."""
    monkeypatch.delenv("REPRO_QUANT_AGG", raising=False)
    rt = {"straggler_prob": 0.2, "duration_sigma": 0.25,
          "availability": 0.85}
    runs = {}
    for chunk in (1, 4, 3):
        ex = Executor(_job(chunk, mode="async", async_buffer=async_buffer,
                           runtime=rt)).scaffold()
        state, _ = ex.run()
        runs[chunk] = _params(state)
    _assert_bitwise_equal(runs[1], runs[4])
    _assert_bitwise_equal(runs[1], runs[3])


def test_packed_sync_chunked_equals_unchunked(monkeypatch):
    monkeypatch.delenv("REPRO_QUANT_AGG", raising=False)
    runs = {}
    for chunk in (1, 4, 3):
        state, _ = Executor(_job(chunk)).scaffold().run()
        runs[chunk] = _params(state)
    _assert_bitwise_equal(runs[1], runs[4])
    _assert_bitwise_equal(runs[1], runs[3])


def test_topk_does_not_take_packed_path(monkeypatch):
    """Only int8 packs; topk still flows through the dense postprocess
    (its sends are sparse f32, not block-quantized)."""
    monkeypatch.delenv("REPRO_QUANT_AGG", raising=False)
    jax.clear_caches()
    ops.reset_quant_agg_stats()
    job = _job(compression="topk", topk_ratio=0.2)
    assert not job.strategy.packs_deltas
    Executor(job).scaffold().run()
    assert ops.quant_agg_stats()["calls"] == 0
