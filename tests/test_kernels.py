"""Per-kernel interpret-mode validation against the pure-jnp oracles.

Sweeps shapes/dtypes per the deliverable: every Pallas kernel is asserted
allclose against ref.py, plus the differentiable jnp-blockwise path is checked
against plain-softmax autodiff.
"""
import os

os.environ.setdefault("REPRO_KERNEL_IMPL", "jnp")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.decode_attention import decode_attention_fwd
from repro.kernels.quant_aggregate import quant_aggregate as pallas_quant_agg
from repro.kernels.rmsnorm import rmsnorm as pallas_rmsnorm

KEY = jax.random.PRNGKey(0)


def rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Sq,Sk,H,KV,Dk,Dv", [
    (2, 128, 128, 4, 4, 64, 64),      # MHA
    (1, 256, 256, 8, 2, 64, 64),      # GQA
    (2, 128, 256, 4, 1, 32, 32),      # MQA, Sq != Sk
    (1, 128, 128, 4, 2, 96, 64),      # MLA dims (Dk != Dv)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_pallas_interpret_vs_ref(B, Sq, Sk, H, KV, Dk, Dv, dtype, causal):
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (B, Sq, H, Dk), dtype)
    k = rand(ks[1], (B, Sk, KV, Dk), dtype)
    v = rand(ks[2], (B, Sk, KV, Dv), dtype)
    offset = Sk - Sq
    out = flash_attention_fwd(q, k, v, causal=causal, block_q=64, block_k=64,
                              q_offset=offset, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, q_offset=offset)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("causal", [True, False])
def test_blockwise_jnp_matches_ref(causal):
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (2, 128, 8, 64), jnp.float32)
    k = rand(ks[1], (2, 128, 2, 64), jnp.float32)
    v = rand(ks[2], (2, 128, 2, 64), jnp.float32)
    out = ops.flash_attention(q, k, v, 0, causal, None, 32, 32)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_backward_matches_autodiff():
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (1, 64, 4, 32), jnp.float32)
    k = rand(ks[1], (1, 64, 2, 32), jnp.float32)
    v = rand(ks[2], (1, 64, 2, 32), jnp.float32)

    def f_flash(q, k, v):
        return (ops.flash_attention(q, k, v, 0, True, None, 32, 32) ** 2).sum()

    def f_ref(q, k, v):
        return (ref.flash_attention_ref(q, k, v, causal=True) ** 2).sum()

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,D", [(2, 256, 8, 2, 64), (1, 512, 4, 4, 128),
                                        (3, 128, 8, 1, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_pallas_interpret_vs_ref(B, S, H, KV, D, dtype):
    ks = jax.random.split(KEY, 4)
    q = rand(ks[0], (B, H, D), dtype)
    k = rand(ks[1], (B, S, KV, D), dtype)
    v = rand(ks[2], (B, S, KV, D), dtype)
    length = jax.random.randint(ks[3], (B,), 1, S + 1)
    o, m, l = decode_attention_fwd(q, k, v, length, block_k=64, interpret=True)
    got = o / np.maximum(np.asarray(l)[..., None], 1e-30)
    want = ref.decode_attention_ref(q, k, v, length)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_decode_blockwise_jnp_matches_ref():
    ks = jax.random.split(KEY, 4)
    q = rand(ks[0], (2, 8, 64), jnp.float32)
    k = rand(ks[1], (2, 256, 2, 64), jnp.float32)
    v = rand(ks[2], (2, 256, 2, 64), jnp.float32)
    length = jnp.array([100, 256])
    got = ops.decode_attention(q, k, v, length, block_k=64)
    want = ref.decode_attention_ref(q, k, v, length)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_decode_lse_combine_across_shards():
    """Chunk-parallel decode: combining per-shard (o,m,l) == full attention."""
    ks = jax.random.split(KEY, 4)
    B, S, H, KV, D = 2, 256, 8, 2, 64
    q = rand(ks[0], (B, H, D), jnp.float32)
    k = rand(ks[1], (B, S, KV, D), jnp.float32)
    v = rand(ks[2], (B, S, KV, D), jnp.float32)
    length = jnp.array([200, 256])
    nsh = 4
    chunks = []
    for i in range(nsh):
        ck = k[:, i * (S // nsh):(i + 1) * (S // nsh)]
        cv = v[:, i * (S // nsh):(i + 1) * (S // nsh)]
        clen = jnp.clip(length - i * (S // nsh), 0, S // nsh)
        o, m, l = ops.decode_attention(q, ck, cv, clen, block_k=32,
                                       combine=False)
        chunks.append((o, m, l))
    m_glob = jnp.max(jnp.stack([m for _, m, _ in chunks]), 0)
    l_glob = sum(l * jnp.exp(m - m_glob) for _, m, l in chunks)
    o_glob = sum(o * jnp.exp(m - m_glob)[..., None] for o, m, l in chunks)
    got = o_glob / jnp.maximum(l_glob, 1e-30)[..., None]
    want = ref.decode_attention_ref(q, k, v, length)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# rmsnorm / quant aggregate
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(64, 128), (3, 40, 256), (130, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_interpret_vs_ref(shape, dtype):
    ks = jax.random.split(KEY, 2)
    x = rand(ks[0], shape, dtype)
    w = rand(ks[1], shape[-1:], jnp.float32)
    got = pallas_rmsnorm(x, w, block_rows=32, interpret=True)
    want = ref.rmsnorm_ref(x, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("C,N,qblock", [(4, 8192, 256), (10, 4096, 128),
                                        (32, 16384, 512)])
def test_quant_aggregate_interpret_vs_ref(C, N, qblock):
    ks = jax.random.split(KEY, 3)
    qd = jax.random.randint(ks[0], (C, N), -127, 128, jnp.int8)
    sc = jax.random.uniform(ks[1], (C, N // qblock), jnp.float32, 1e-4, 1e-2)
    w = jax.random.uniform(ks[2], (C,), jnp.float32)
    w = w / w.sum()
    got = pallas_quant_agg(qd, sc, w, block_n=2048, interpret=True)
    want = ref.quant_aggregate_ref(qd, sc, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(KEY, (8192,), jnp.float32)
    q, sc = ops.quantize_blockwise(x, block=256)
    deq = ref.quant_aggregate_ref(q[None], sc[None], jnp.ones((1,)))
    err = np.abs(np.asarray(deq - x))
    amax = np.abs(np.asarray(x).reshape(-1, 256)).max(1, keepdims=True)
    bound = np.repeat(amax / 127.0, 256, 1).reshape(-1) / 2 + 1e-7
    assert (err <= bound + 1e-6).all()


# ---------------------------------------------------------------------------
# quant_aggregate dispatcher (ops-level: the path compressed drivers call)
# ---------------------------------------------------------------------------

def _qagg_inputs(C, N, qblock, key=KEY):
    ks = jax.random.split(key, 3)
    qd = jax.random.randint(ks[0], (C, N), -127, 128, jnp.int8)
    sc = jax.random.uniform(ks[1], (C, N // qblock), jnp.float32, 1e-4, 1e-2)
    w = jax.random.uniform(ks[2], (C,), jnp.float32)
    return qd, sc, w / w.sum()


@pytest.mark.parametrize("C,N,qblock", [(4, 8192, 256), (7, 4096, 128),
                                        (1, 2048, 256)])
def test_quant_agg_fused_equals_dequant_first_bitwise(C, N, qblock):
    """The BENCH_agg contract's correctness half: the fused path and the
    dequant-first reference share per-client arithmetic and accumulation
    order, so they must agree bit-for-bit, not just allclose."""
    qd, sc, w = _qagg_inputs(C, N, qblock)
    fused = ops._quant_agg_fused(qd, sc, w)
    dequant = ops._quant_agg_dequant_first(qd, sc, w)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(dequant))


@pytest.mark.parametrize("N,qblock", [(1280, 256), (4096 + 128, 128),
                                      (512, 512)])
def test_quant_aggregate_pad_and_mask_non_divisible(N, qblock, monkeypatch):
    """Pytree packing yields N that rarely divides the kernel tile: the
    interpret-path wrapper must zero-pad up to whole tiles and slice the
    pad back off, matching the unpadded jnp reference."""
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "interpret")
    qd, sc, w = _qagg_inputs(5, N, qblock)
    got = ops.quant_aggregate(qd, sc, w)
    assert got.shape == (N,)
    want = ref.quant_aggregate_ref(qd, sc, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_quant_aggregate_vmap_falls_back_to_fused(monkeypatch):
    """Under a campaign lane vmap the Pallas wrapper can't run (pallas_call
    doesn't trace through a batched dim here); the dispatcher must fall
    back to the fused jnp path — warning + counter, bitwise per-lane."""
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "interpret")
    L, C, N, qblock = 3, 4, 2048, 256
    ks = jax.random.split(KEY, 3)
    qd = jax.random.randint(ks[0], (L, C, N), -127, 128, jnp.int8)
    sc = jax.random.uniform(ks[1], (L, C, N // qblock), jnp.float32,
                            1e-4, 1e-2)
    w = jax.random.uniform(ks[2], (L, C), jnp.float32)
    ops.reset_quant_agg_stats()
    with pytest.warns(UserWarning, match="vmapped"):
        got = jax.vmap(ops.quant_aggregate)(qd, sc, w)
    stats = ops.quant_agg_stats()
    assert stats["calls"] == 1 and stats["batched_fallbacks"] == 1
    assert stats["last_impl"] == "jnp-fused(vmap-fallback)"
    for lane in range(L):
        np.testing.assert_array_equal(
            np.asarray(got[lane]),
            np.asarray(ops._quant_agg_fused(qd[lane], sc[lane], w[lane])))


def test_quant_aggregate_rejects_unknown_mode(monkeypatch):
    monkeypatch.setenv("REPRO_QUANT_AGG", "fussed")
    qd, sc, w = _qagg_inputs(2, 1024, 256)
    with pytest.raises(ValueError, match="REPRO_QUANT_AGG"):
        ops.quant_aggregate(qd, sc, w)


@pytest.mark.skipif(jax.default_backend() != "tpu",
                    reason="compiled Pallas path needs a TPU backend")
def test_quant_aggregate_pallas_compiled_vs_ref():
    """TPU-only: the compiled (non-interpret) kernel against the jnp
    oracle — a capability skip on CPU runners, never a silent pass."""
    qd, sc, w = _qagg_inputs(8, 1 << 16, 256)
    got = pallas_quant_agg(qd, sc, w, block_n=4096, interpret=False)
    want = ref.quant_aggregate_ref(qd, sc, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
