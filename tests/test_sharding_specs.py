"""Spec-table validation: every sharded dim divides the production mesh, and
the spec tree matches the param tree for all (arch x phase)."""
import math

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ARCHS, get_config
from repro.models import transformer
from repro.sharding import specs as sspecs

MESH_SIZES = {"data": 16, "model": 16, "pod": 2}


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("phase", ["fsdp", "tp", "spatial"])
def test_specs_match_and_divide(arch, phase):
    cfg = get_config(arch)
    shapes = transformer.param_shapes(cfg)
    specs = sspecs.param_specs(cfg, phase)
    flat_sh = jax.tree_util.tree_flatten_with_path(
        shapes, is_leaf=lambda x: isinstance(x, tuple))[0]
    flat_sp = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_sh) == len(flat_sp), f"{arch}/{phase}: tree mismatch"
    for (path, shape), spec in zip(flat_sh, flat_sp):
        for dim, entry in enumerate(spec):
            if entry is None:
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            factor = math.prod(MESH_SIZES[n] for n in names)
            assert shape[dim] % factor == 0, (
                f"{arch}/{phase} {jax.tree_util.keystr(path)}: dim {dim} "
                f"size {shape[dim]} not divisible by {names}={factor}")


@pytest.mark.parametrize("arch", ARCHS)
def test_gather_table_consistent(arch):
    cfg = get_config(arch)
    table = sspecs.gather_dim_table(cfg)   # asserts internally on conflicts
    assert isinstance(table, dict) and table


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_invariant_across_phases(arch):
    """Sharding must never change the parameter count (incl. subgrid packing)."""
    cfg = get_config(arch)
    shapes = transformer.param_shapes(cfg)
    n = sum(math.prod(s) for s in jax.tree.leaves(
        shapes, is_leaf=lambda x: isinstance(x, tuple)))
    assert n > 0
    if cfg.moe is not None and cfg.moe.ep_mode == "subgrid":
        m = cfg.moe
        # packed (E*f_sub, D, F/f_sub) == E*D*F
        blocks = shapes["blocks"]["moe"]["w1"]
        L = blocks[0]
        assert blocks[1] == m.n_experts * m.f_sub
        assert blocks[3] == m.expert_d_ff // m.f_sub
