"""Campaign planner + lane scheduler tests (heterogeneous sweeps).

Extends the PR 3 campaign contract to categorical axes: a heterogeneous
strategy x topology x seed grid buckets by program signature, each bucket
runs as one vmapped launch, and — scheduler off — every lane is bitwise
identical to its independent single run. With successive halving on,
dropped lanes freeze at their drop round (bitwise a truncated single run),
survivors stay bitwise their full single runs, and drops land in the
ledger. Plus the satellites: bucketer properties, data-plane dedup, and
the append-only results table.
"""
import itertools
import os

os.environ.setdefault("REPRO_KERNEL_IMPL", "jnp")

import jax
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core import sweeps
from repro.core.jobs import load_job
from repro.core.plan import build_plan, program_signature
from repro.runtime.campaign import CampaignExecutor, read_results
from repro.runtime.executor import Executor
from repro.runtime.scheduler import PlanExecutor, SuccessiveHalving


def _raw(coord=None, sweep=None, *, mode="sync", rounds=2, chunk=1,
         n_clients=4, n_items=96, arch="flsim-logreg", blockchain="none"):
    """One job dict; ``coord`` overrides (categorical + scalar) land in
    their proper sections — the single-run references for each campaign
    lane are built this way."""
    coord = coord or {}
    tp = {"n_clients": n_clients, "local_epochs": 1,
          "client_lr": coord.get("client_lr", 0.1),
          "rounds": rounds, "seed": coord.get("seed", 3),
          "rounds_per_launch": chunk,
          "topology": coord.get("topology", "client_server"),
          "placement": coord.get("placement", "auto"),
          "blockchain": blockchain}
    if mode == "async" or coord.get("mode") == "async":
        tp.update({"mode": "async",
                   "async_buffer": coord.get("async_buffer", 3),
                   "max_staleness": 4, "staleness_exponent": 0.5})
    return {
        "name": "plan-test",
        "model": {"arch": arch},
        "dataset": {"dataset": "synthetic_vision", "n_items": n_items,
                    "distribution": {
                        "partition": "dirichlet",
                        "dirichlet_alpha": coord.get("dirichlet_alpha",
                                                     0.5)}},
        "strategy": {"strategy": coord.get("strategy", "fedavg"),
                     "train_params": tp},
        **({"sweep": sweep} if sweep else {}),
    }


def _assert_bitwise_equal(p1, p2):
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# categorical axis parsing / validation
# ---------------------------------------------------------------------------

def test_categorical_axis_value_near_miss():
    with pytest.raises(KeyError, match="fedprox"):
        sweeps.parse_sweep({"strategy": ["fedprx"]})
    with pytest.raises(KeyError, match="hierarchical"):
        sweeps.parse_sweep({"topology": ["hierarchal"]})
    with pytest.raises(KeyError, match="async"):
        sweeps.parse_sweep({"mode": ["asinc"]})


def test_categorical_axis_name_near_miss():
    with pytest.raises(KeyError, match="topology"):
        sweeps.parse_sweep({"topolgy": ["client_server"]})


def test_duplicate_axis_values_rejected():
    with pytest.raises(ValueError, match="repeats"):
        sweeps.parse_sweep({"strategy": ["fedavg", "fedavg"]})
    with pytest.raises(ValueError, match="repeats"):
        sweeps.parse_sweep({"seeds": [1, 1]})


def test_mixed_grid_exact_cross_product():
    spec = sweeps.parse_sweep({"strategy": ["fedavg", "fedprox"],
                               "seeds": [0, 1], "client_lr": [0.1, 0.2]})
    coords = spec.coords()
    want = [dict(zip(("strategy", "seed", "client_lr"), c))
            for c in itertools.product(("fedavg", "fedprox"), (0, 1),
                                       (0.1, 0.2))]
    assert coords == want
    assert spec.size == 8 == len(coords)
    assert len({tuple(sorted(c.items())) for c in coords}) == 8  # no dups
    assert spec.categorical_names == ("strategy",)


# ---------------------------------------------------------------------------
# program signatures + bucketing
# ---------------------------------------------------------------------------

def test_signature_canonicalization():
    base = FLConfig()
    # placement auto resolves before hashing
    assert program_signature(base.__class__(placement="auto")) == \
        program_signature(base.__class__(placement="spatial"))
    # FedAsync: buffer 0 and 1 are the same event loop
    assert program_signature(FLConfig(mode="async", async_buffer=0)) == \
        program_signature(FLConfig(mode="async", async_buffer=1))
    # sync programs never read async knobs
    assert program_signature(FLConfig(max_staleness=4)) == \
        program_signature(FLConfig(max_staleness=8))
    # the async event loop has no topology/placement
    assert program_signature(
        FLConfig(mode="async", topology="client_server")) == \
        program_signature(FLConfig(mode="async", topology="hierarchical"))
    # but the scalar plane never splits signatures
    assert program_signature(FLConfig(client_lr=0.1)) == \
        program_signature(FLConfig(client_lr=0.5))
    # and structural axes do
    assert program_signature(FLConfig(strategy="fedavg")) != \
        program_signature(FLConfig(strategy="fedprox"))
    assert program_signature(FLConfig(mode="async", async_buffer=3)) != \
        program_signature(FLConfig(mode="async", async_buffer=4))


def _check_plan_invariants(section):
    spec = sweeps.parse_sweep(section)
    p = build_plan(FLConfig(), spec, arch="flsim-logreg")
    # buckets partition the grid exactly
    all_lanes = sorted(i for b in p.buckets for i in b.lane_ids)
    assert all_lanes == list(range(p.size))
    assert p.size == spec.size == len(list(
        itertools.product(*(v for _, v in spec.axes))))
    # same bucket <=> equal signature
    for b in p.buckets:
        assert all(p.signatures[i] == b.signature for i in b.lane_ids)
    sigs = {b.signature for b in p.buckets}
    assert len(sigs) == len(p.buckets)
    # lane_bucket round-trips
    for lane in range(p.size):
        bi, j = p.lane_bucket(lane)
        assert p.buckets[bi].lane_ids[j] == lane


def test_bucketer_invariants_fixed_grids():
    _check_plan_invariants({"strategy": ["fedavg", "fedprox", "scaffold"],
                            "topology": ["client_server", "hierarchical"],
                            "seeds": [0, 1]})
    _check_plan_invariants({"placement": ["auto", "spatial", "temporal"],
                            "client_lr": [0.1, 0.2]})
    _check_plan_invariants({"mode": ["sync", "async"], "seeds": [0, 1, 2]})
    _check_plan_invariants({"async_buffer": [0, 1, 4], "seeds": [0, 1]})


def test_bucketer_property_equal_signature_iff_same_bucket():
    pytest.importorskip("hypothesis", reason="property tests need hypothesis")
    from hypothesis import given, settings, strategies as st

    axis_pool = {
        "strategy": ["fedavg", "fedprox", "fedavgm", "scaffold"],
        "topology": ["client_server", "hierarchical", "decentralized"],
        "placement": ["auto", "spatial"],
        "mode": ["sync", "async"],
        "async_buffer": [0, 1, 3],
        "seed": [0, 1, 2],
        "client_lr": [0.05, 0.1, 0.2],
    }

    @settings(max_examples=25, deadline=None)
    @given(st.data())
    def inner(data):
        section = {}
        for name, pool in axis_pool.items():
            vals = data.draw(st.lists(st.sampled_from(pool), min_size=0,
                                      max_size=len(pool), unique=True))
            if vals:
                section[name] = vals
        if not section:
            section = {"seed": [0]}
        spec = sweeps.parse_sweep(section)
        p = build_plan(FLConfig(), spec, arch="flsim-mlp")
        _check_plan_invariants(section)
        # pairwise: same bucket <=> equal signatures
        lane_of = {i: b.index for b in p.buckets for i in b.lane_ids}
        for i in range(p.size):
            for j in range(i + 1, p.size):
                same = lane_of[i] == lane_of[j]
                assert same == (p.signatures[i] == p.signatures[j])

    inner()


def test_placement_auto_and_spatial_share_a_bucket():
    spec = sweeps.parse_sweep({"placement": ["auto", "spatial", "temporal"]})
    p = build_plan(FLConfig(), spec, arch="flsim-logreg")
    assert len(p.buckets) == 2
    assert p.buckets[0].lane_ids == (0, 1)     # auto == spatial


# ---------------------------------------------------------------------------
# heterogeneous execution: the bitwise contract, scheduler off
# ---------------------------------------------------------------------------

def test_heterogeneous_sync_campaign_bitwise_equals_single_runs():
    """strategy x topology x seed: 8 lanes, 4 program signatures, every
    lane bitwise its independent single run."""
    sweep = {"strategy": ["fedavg", "fedprox"],
             "topology": ["client_server", "hierarchical"],
             "seeds": [3, 5]}
    pe = PlanExecutor(load_job(_raw(sweep=sweep))).scaffold()
    assert pe.S == 8 and len(pe.plan.buckets) == 4
    pe.run()
    for lane, coord in enumerate(pe.plan.coords):
        state, _ = Executor(load_job(_raw(coord))).scaffold().run()
        _assert_bitwise_equal(jax.tree.map(np.asarray, state["params"]),
                              pe.lane_params(lane))


def test_heterogeneous_compression_campaign_bitwise_equals_single_runs():
    """compression x seed under the compressed strategy: dense, packed
    int8 (kernels/ops.quant_aggregate) and topk aggregation are three
    different traced programs -> 3 buckets, every lane bitwise its
    independent single run."""
    def mk(coord=None, sweep=None):
        raw = _raw(coord, sweep=sweep)
        raw["strategy"]["strategy"] = "compressed"
        raw["strategy"]["train_params"].update(
            {"compression": (coord or {}).get("compression", "none"),
             "error_feedback": True})
        return raw

    sweep = {"compression": ["none", "int8", "topk"], "seeds": [3, 5]}
    pe = PlanExecutor(load_job(mk(sweep=sweep))).scaffold()
    assert pe.S == 6 and len(pe.plan.buckets) == 3
    pe.run()
    for lane, coord in enumerate(pe.plan.coords):
        state, _ = Executor(load_job(mk(coord))).scaffold().run()
        _assert_bitwise_equal(jax.tree.map(np.asarray, state["params"]),
                              pe.lane_params(lane))


def test_heterogeneous_async_campaign_bitwise_equals_single_runs():
    """Async buckets: strategy x seed under FedBuff, lanes bitwise their
    single runs (event scan + per-lane schedules under the bucket vmap)."""
    sweep = {"strategy": ["fedavg", "fedprox"], "seeds": [7, 9]}
    pe = PlanExecutor(
        load_job(_raw({"seed": 7}, sweep=sweep, mode="async",
                      chunk=2))).scaffold()
    assert pe.S == 4 and len(pe.plan.buckets) == 2
    pe.run()
    for lane, coord in enumerate(pe.plan.coords):
        state, _ = Executor(
            load_job(_raw(coord, mode="async", chunk=2))).scaffold().run()
        _assert_bitwise_equal(jax.tree.map(np.asarray, state["params"]),
                              pe.lane_params(lane))


def test_24_point_grid_compiles_exactly_4_programs(tmp_path):
    """The tentpole claim: 24 trajectories, 4 signatures -> 4 compiled
    programs (compile-count instrumentation), one merged table keyed by
    (bucket, lane, sweep coords)."""
    sweep = {"strategy": ["fedavg", "fedprox"],
             "topology": ["client_server", "hierarchical"],
             "seeds": [3, 5, 7], "client_lr": [0.05, 0.1]}
    pe = PlanExecutor(load_job(_raw(sweep=sweep, rounds=1)),
                      out_dir=str(tmp_path)).scaffold()
    assert pe.S == 24 and len(pe.plan.buckets) == 4
    pe.run()
    assert pe.compiled_programs() == 4
    rows = pe.rows()
    assert len(rows) == 24
    assert {"bucket", "lane", "strategy", "topology", "seed", "client_lr",
            "traj", "round", "loss"} <= set(rows[0])
    assert sorted(r["lane"] for r in rows) == list(range(24))
    # the merged table round-trips through its CSV
    got = read_results(tmp_path / "campaign.csv")
    assert len(got) == 24
    assert got[0]["strategy"] in ("fedavg", "fedprox")
    header = (tmp_path / "campaign.csv").read_text().splitlines()[0]
    assert header.startswith("bucket,lane,strategy,topology,seed,client_lr")
    # cross-strategy curves group the merged table by strategy alone
    from benchmarks.figures import strategy_comparison
    curves = strategy_comparison(tmp_path / "campaign.csv")
    assert {c["group"]["strategy"] for c in curves} == {"fedavg", "fedprox"}
    assert all(len(c["rounds"]) == 1 for c in curves)


def test_campaign_executor_rejects_heterogeneous_sweep():
    raw = _raw(sweep={"strategy": ["fedavg", "fedprox"], "seeds": [3, 5]})
    with pytest.raises(ValueError, match="PlanExecutor"):
        CampaignExecutor(load_job(raw))


# ---------------------------------------------------------------------------
# lane scheduler: successive halving
# ---------------------------------------------------------------------------

def test_successive_halving_policy():
    sh = SuccessiveHalving(metric="loss", rung_every=2, eta=2.0,
                           min_lanes=1)
    metrics = {0: 0.5, 1: 0.1, 2: 0.9, 3: 0.3}
    assert sh.decide(1, metrics) == []            # off-rung
    assert sorted(sh.decide(2, metrics)) == [0, 2]  # keep best half
    assert sh.decide(2, {0: 0.5}) == []           # min_lanes floor
    sh_max = SuccessiveHalving(metric="acc", mode="max", rung_every=1)
    assert sorted(sh_max.decide(1, metrics)) == [1, 3]
    with pytest.raises(ValueError, match="eta"):
        SuccessiveHalving(eta=1.0)
    # rung *crossing*: boundaries need not land exactly on a multiple
    sh5 = SuccessiveHalving(rung_every=5)
    assert not sh5.is_rung(4, prev_round=0)
    assert sh5.is_rung(8, prev_round=4)       # rung 5 crossed in (4, 8]
    assert not sh5.is_rung(8, prev_round=5)
    assert sorted(sh5.decide(8, metrics, prev_round=4)) == [0, 2]


def test_scheduled_checkpointed_campaign_requires_out_dir(tmp_path):
    pe = PlanExecutor(load_job(_raw(sweep={"seeds": [3, 5]})),
                      scheduler=SuccessiveHalving(),
                      ckpt_dir=str(tmp_path / "ck"))
    with pytest.raises(ValueError, match="out_dir"):
        pe.scaffold()


def test_halving_drops_lanes_and_freezes_their_state():
    """4 seed lanes, halving every round over 3 rounds -> 1 survivor.
    Dropped lanes freeze bitwise at their drop round (no recompilation:
    still one compiled program); the survivor stays bitwise its full
    single run; drops are ledger-recorded; dropped lanes stop contributing
    rows beyond their drop round."""
    sweep = {"seeds": [3, 5, 7, 9]}
    raw = _raw(sweep=sweep, rounds=3, blockchain="hashchain")
    pe = PlanExecutor(load_job(raw),
                      scheduler=SuccessiveHalving(rung_every=1)).scaffold()
    pe.run()
    assert len(pe.dropped) == 3
    survivors = [ln for ln in range(pe.S) if ln not in pe.dropped]
    assert len(survivors) == 1
    assert pe.compiled_programs() == 1            # drops never recompile

    for lane, coord in enumerate(pe.plan.coords):
        stop = pe.dropped.get(lane)               # None -> ran to the end
        ex = Executor(load_job(_raw(coord, rounds=3))).scaffold()
        state, _ = ex.run(rounds=stop)
        _assert_bitwise_equal(jax.tree.map(np.asarray, state["params"]),
                              pe.lane_params(lane))

    # drop decisions are on the chain, with the deciding metric
    drops = [b for b in pe.job.ledger.blocks() if b.kind == "lane_drop"]
    assert len(drops) == 3 and pe.job.ledger.verify()
    assert all("loss" in b.payload and "coord" in b.payload for b in drops)

    # dropped lanes stop contributing rows beyond their drop round
    for r in pe.rows():
        stop = pe.dropped.get(r["lane"])
        assert stop is None or r["round"] < stop


def test_halving_resume_replays_chunk_boundary_decisions(tmp_path):
    """Resume must reconstruct exactly the drops the live lockstep made:
    with rung_every=1 but rounds_per_launch=2, decisions only happen at
    chunk boundaries (rounds 2, 4), and a replay that evaluated every rung
    round would drop different lanes from round-0 metrics."""
    sweep = {"seeds": [3, 5, 7, 9]}

    def mk():
        raw = _raw(sweep=sweep, rounds=4, chunk=2)
        raw["strategy"]["train_params"]["checkpoint_every"] = 2
        return PlanExecutor(load_job(raw),
                            scheduler=SuccessiveHalving(rung_every=1),
                            ckpt_dir=str(tmp_path / "ckpt"),
                            out_dir=str(tmp_path / "out"))

    full = PlanExecutor(load_job(_raw(sweep=sweep, rounds=4, chunk=2)),
                        scheduler=SuccessiveHalving(rung_every=1)).scaffold()
    full.run()

    pe1 = mk().scaffold()
    pe1.run(rounds=2)                       # crash after the first boundary
    pe2 = mk().scaffold()                   # resumes at round 2
    assert pe2.round_idx == 2
    assert pe2.dropped == {ln: r for ln, r in full.dropped.items() if r <= 2}
    pe2.run()
    assert pe2.dropped == full.dropped
    for lane in range(full.S):
        _assert_bitwise_equal(full.lane_params(lane), pe2.lane_params(lane))


def test_unknown_scheduler_metric_fails_loudly():
    """A typo'd metric must not silently disable halving — same no-silent-
    typos contract as every other config surface."""
    pe = PlanExecutor(load_job(_raw(sweep={"seeds": [3, 5]}, rounds=2)),
                      scheduler=SuccessiveHalving(metric="los",
                                                  rung_every=1)).scaffold()
    with pytest.raises(KeyError, match="loss"):
        pe.run()


# ---------------------------------------------------------------------------
# satellite: data-plane dedup
# ---------------------------------------------------------------------------

def test_scalar_only_sweep_stages_one_dataset():
    """Lanes sharing the data-plane triple share ONE staged root: staged
    bytes shrink vs the stacked staging, and the results stay bitwise
    (asserted against single runs, the strongest form)."""
    from repro.data.pipeline import stage_partitions_stacked

    sweep = {"client_lr": [0.05, 0.1, 0.2, 0.4]}
    camp = CampaignExecutor(load_job(_raw(sweep=sweep))).scaffold()
    assert camp.S == 4
    np.testing.assert_array_equal(camp.lane_ds, [0, 0, 0, 0])
    stacked = stage_partitions_stacked(camp.trajectories)
    root_bytes = lambda st: st["x"].nbytes + st["y"].nbytes
    assert root_bytes(camp.staged) * 4 == root_bytes(stacked)
    camp.run()
    for s, coord in enumerate(camp.spec.coords()):
        state, _ = Executor(load_job(_raw(coord))).scaffold().run()
        _assert_bitwise_equal(jax.tree.map(np.asarray, state["params"]),
                              camp.trajectory_params(s))


def test_mixed_sweep_dedups_per_distinct_data_plane():
    sweep = {"seeds": [3, 5], "client_lr": [0.05, 0.1]}
    camp = CampaignExecutor(load_job(_raw(sweep=sweep))).scaffold()
    # row-major: seed varies slowest -> lanes (0,1) share seed 3's root
    np.testing.assert_array_equal(camp.lane_ds, [0, 0, 1, 1])
    assert camp.staged["idx"].shape[0] == 4       # per-lane planes keep S


# ---------------------------------------------------------------------------
# satellite: append-only results table
# ---------------------------------------------------------------------------

def test_results_table_appends_instead_of_rewriting(tmp_path):
    """5 chunks -> 1 header write + 4 appends, never a per-chunk rewrite;
    the file stays byte-consistent with the in-memory rows."""
    sweep = {"seeds": [3, 5]}
    raw = _raw(sweep=sweep, rounds=5, chunk=1)
    camp = CampaignExecutor(load_job(raw), out_dir=str(tmp_path)).scaffold()
    camp.eval_fn = lambda params: {
        "pnorm": float(sum(np.abs(np.asarray(t)).sum()
                           for t in jax.tree.leaves(params)))}
    camp.run()
    assert camp._table.rewrites == 1
    assert camp._table.appends == 4
    got = read_results(tmp_path / "campaign.csv")
    assert len(got) == len(camp.results) == 2 * 5
    for g, r in zip(got, camp.results):
        assert g["round"] == r["round"] and g["traj"] == r["traj"]
        np.testing.assert_allclose(g["loss"], r["loss"], rtol=1e-6)
        if "pnorm" in r:
            np.testing.assert_allclose(g["pnorm"], r["pnorm"], rtol=1e-6)


def test_resume_readopts_then_appends(tmp_path):
    """A resumed campaign rewrites once (re-adopting the prior table) and
    appends afterwards — the full-table O(S*R^2) behavior is gone."""
    sweep = {"seeds": [3, 5]}

    def mk(out):
        raw = _raw(sweep=sweep, rounds=4, chunk=1)
        raw["strategy"]["train_params"]["checkpoint_every"] = 2
        return CampaignExecutor(load_job(raw), out_dir=str(out),
                                ckpt_dir=str(tmp_path / "ckpt"))

    ex = mk(tmp_path / "a").scaffold()
    ex.run(rounds=2)
    ex2 = mk(tmp_path / "a").scaffold()
    assert ex2.round_idx == 2 and len(ex2.results) == 2 * 2
    ex2.run()
    # one rewrite (re-adopting rounds 0-1 + the round-2 chunk), then pure
    # appends for the remaining chunk
    assert ex2._table.rewrites == 1 and ex2._table.appends == 1
    got = read_results(tmp_path / "a" / "campaign.csv")
    assert sorted({r["round"] for r in got}) == [0, 1, 2, 3]
    assert len(got) == 2 * 4
