"""Property-based tests (hypothesis) on core system invariants."""
import os

os.environ.setdefault("REPRO_KERNEL_IMPL", "jnp")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


def _qkv(seed, B, S, H, KV, D):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (B, S, H, D)),
            jax.random.normal(ks[1], (B, S, KV, D)),
            jax.random.normal(ks[2], (B, S, KV, D)))


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_attention_causality(seed):
    """Perturbing future K/V must not change past outputs."""
    q, k, v = _qkv(seed, 1, 64, 4, 2, 16)
    out1 = ops.flash_attention(q, k, v, 0, True, None, 32, 32)
    k2 = k.at[:, 48:].add(100.0)
    v2 = v.at[:, 48:].add(-50.0)
    out2 = ops.flash_attention(q, k2, v2, 0, True, None, 32, 32)
    np.testing.assert_allclose(np.asarray(out1[:, :48]),
                               np.asarray(out2[:, :48]), atol=1e-5)
    assert not np.allclose(np.asarray(out1[:, 49:]), np.asarray(out2[:, 49:]))


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_attention_batch_permutation_equivariance(seed):
    q, k, v = _qkv(seed, 4, 32, 4, 4, 16)
    perm = np.random.RandomState(seed).permutation(4)
    out = ops.flash_attention(q, k, v, 0, True, None, 32, 32)
    out_p = ops.flash_attention(q[perm], k[perm], v[perm], 0, True, None,
                                32, 32)
    np.testing.assert_allclose(np.asarray(out[perm]), np.asarray(out_p),
                               atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([16, 32, 64]))
def test_attention_block_size_invariance(seed, blk):
    """Flash output must not depend on the tiling."""
    q, k, v = _qkv(seed, 2, 64, 4, 2, 16)
    a = ops.flash_attention(q, k, v, 0, True, None, 64, 64)
    b = ops.flash_attention(q, k, v, 0, True, None, blk, blk)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_seq_shard_offset_consistency(seed):
    """Sharded q rows with the right offsets reproduce the full output."""
    q, k, v = _qkv(seed, 1, 64, 4, 2, 16)
    full = ops.flash_attention(q, k, v, 0, True, None, 32, 32)
    parts = [ops.flash_attention(q[:, i * 16:(i + 1) * 16], k, v, i * 16,
                                 True, None, 16, 32) for i in range(4)]
    np.testing.assert_allclose(np.asarray(jnp.concatenate(parts, 1)),
                               np.asarray(full), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 8))
def test_quantize_scale_invariance_of_sign(seed, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), (1024,))
    q1, _ = ops.quantize_blockwise(x, block=128)
    q2, _ = ops.quantize_blockwise(x * scale, block=128)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_decode_attention_length_monotone(seed):
    """With length=S decode equals the full-window reference; with length=1
    it attends only the first position."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    B, S, H, KV, D = 2, 64, 4, 2, 16
    q = jax.random.normal(ks[0], (B, H, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    out_full = ops.decode_attention(q, k, v, jnp.full((B,), S), block_k=16)
    want = ref.decode_attention_ref(q, k, v, jnp.full((B,), S))
    np.testing.assert_allclose(np.asarray(out_full), np.asarray(want),
                               atol=1e-5)
    out_one = ops.decode_attention(q, k, v, jnp.ones((B,), jnp.int32),
                                   block_k=16)
    # attending one position == that position's v (per kv head group)
    vv = jnp.repeat(v[:, 0], H // KV, axis=1).reshape(B, H, D)
    np.testing.assert_allclose(np.asarray(out_one), np.asarray(vv),
                               atol=1e-5)
