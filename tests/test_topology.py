"""Topology reduction-plan tests (paper Fig. 4 / RQ5).

Covers the identities the aggregation plans promise: hierarchical collapses
to client-server when there is no pod tier, gossip mixing is doubly
stochastic (preserves the client mean), and the meshless roll-based gossip
ring agrees with the real ppermute ring on a forced-device mesh.
"""
import os
import subprocess
import sys

os.environ.setdefault("REPRO_KERNEL_IMPL", "jnp")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.topology import (ClientServer, Decentralized, Hierarchical,
                                 get_topology)
from repro.sharding.axes import AxisCtx


def _deltas(seed=0, n_clients=6, dtype=jnp.float32):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return {"w": jax.random.normal(k1, (n_clients, 5, 3)).astype(dtype),
            "b": jax.random.normal(k2, (n_clients, 4)).astype(dtype)}


@pytest.mark.parametrize("weights", ["equal", "sized"])
def test_hierarchical_equals_client_server_meshless(weights):
    """With no pod tier (meshless / single-pod) the two-tier reduction IS
    the flat weighted mean — clustered and client-server jobs must agree."""
    d = _deltas()
    w = (jnp.ones(6) if weights == "equal"
         else jnp.asarray([1.0, 5.0, 2.0, 7.0, 3.0, 1.0]))
    ctx = AxisCtx()
    flat = ClientServer().aggregate(ctx, d, w)
    tiered = Hierarchical().aggregate(ctx, d, w)
    for a, b in zip(jax.tree.leaves(flat), jax.tree.leaves(tiered)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("gossip_steps", [1, 3])
def test_gossip_mixing_preserves_client_mean(gossip_steps):
    """The ring mixing matrix is doubly stochastic: k gossip steps must
    leave the across-client mean invariant (decentralized FL sanity)."""
    d = _deltas(seed=3)
    mixed = Decentralized(gossip_steps=gossip_steps).mix(AxisCtx(), d)
    for a, b in zip(jax.tree.leaves(d), jax.tree.leaves(mixed)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a).mean(0),
                                   np.asarray(b).mean(0), rtol=1e-5,
                                   atol=1e-6)
        # and it actually mixed something
        assert not np.allclose(np.asarray(a), np.asarray(b))


def test_gossip_meshless_preserves_low_precision_mean():
    """Regression for the meshless ring dtype fix: mixing bf16 state must
    accumulate in f32 (like the ppermute path), so the client mean survives
    at f32 accuracy and the output keeps the input dtype."""
    d = _deltas(seed=5, dtype=jnp.bfloat16)
    mixed = Decentralized(gossip_steps=2).mix(AxisCtx(), d)
    for a, b in zip(jax.tree.leaves(d), jax.tree.leaves(mixed)):
        assert b.dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(a, np.float32).mean(0),
                                   np.asarray(b, np.float32).mean(0),
                                   rtol=0.05, atol=0.05)


def test_gossip_meshless_matches_mesh():
    """The roll-based meshless ring and the ppermute ring are the same
    mixing plan: on a 1-axis forced-device mesh they must agree bitwise
    (subprocess: the device count must be forced before jax initializes)."""
    code = (
        "import os;"
        "os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=4';"
        "os.environ.setdefault('REPRO_KERNEL_IMPL','jnp');"
        "import sys; sys.path.insert(0,'src');"
        "import jax, numpy as np, jax.numpy as jnp;"
        "from jax.sharding import Mesh, PartitionSpec as P;"
        "from jax.experimental.shard_map import shard_map;"
        "from repro.core.topology import Decentralized;"
        "from repro.sharding.axes import AxisCtx;"
        "topo=Decentralized(gossip_steps=3);"
        "x=jax.random.normal(jax.random.PRNGKey(0),(4,8))"
        ".astype(jnp.bfloat16);"
        "mesh=Mesh(np.array(jax.devices()[:4]),('data',));"
        "f=shard_map(lambda t: topo.mix(AxisCtx(data='data'), t), mesh=mesh,"
        " in_specs=P('data'), out_specs=P('data'));"
        "on_mesh=np.asarray(jax.jit(f)(x), np.float32);"
        "meshless=np.asarray(topo.mix(AxisCtx(), x), np.float32);"
        "np.testing.assert_array_equal(on_mesh, meshless);"
        "print('GOSSIP-AGREE OK')"
    )
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "GOSSIP-AGREE OK" in r.stdout


def test_get_topology_registry():
    assert isinstance(get_topology("client_server"), ClientServer)
    assert isinstance(get_topology("hierarchical"), Hierarchical)
    assert get_topology("decentralized", 3).gossip_steps == 3
    with pytest.raises(ValueError, match="unknown topology"):
        get_topology("full-mesh-9000")
    with pytest.raises(ValueError, match="did you mean 'hierarchical'"):
        get_topology("hierarchal")
