"""Round-probe plane tests (core/probes.py + the runtime drain).

The load-bearing contract is that probes are *strictly observational*:
running any driver (sync spatial/temporal, async, campaign) with
``probes: {enabled: true}`` must produce bit-identical params to the same
run with probes off, and probe values themselves must be deterministic
across chunk sizes. On top of that: the probe catalogue lands complete in
``probes.csv`` and as per-lane Perfetto counter tracks, the divergence
sentinel fires on NaN/Inf (and ``on_divergence: freeze`` holds the lane at
its last finite state without recompiling), the async drain adds the
staleness histogram + buffer occupancy, compile launches record
``program_cost`` (Lowered.cost_analysis), and the async ledger-digest
cadence emits a chunking-invariant block count. Satellites: trace-report
self-time edge cases and ``read_events`` tolerance of torn tails.
"""
import json
import os

os.environ.setdefault("REPRO_KERNEL_IMPL", "jnp")

import jax
import numpy as np
import pytest

from repro.core.jobs import load_job
from repro.core.probes import (PROBE_NAMES, ProbeSpec, buffer_occupancy,
                               read_probes, staleness_hist)
from repro.runtime.campaign import CampaignExecutor
from repro.runtime.executor import Executor
from repro.telemetry.recorder import read_events
from repro.telemetry.trace import report, to_chrome_trace

_PROBES_ON = {"enabled": True}


def _raw(*, mode="sync", rounds=4, chunk=2, sweep=None, probes=None,
         telemetry=None, seed=3, strategy="fedavg", **tp_extra):
    tp = {"n_clients": 4, "local_epochs": 1, "client_lr": 0.1,
          "rounds": rounds, "seed": seed, "rounds_per_launch": chunk}
    runtime = {"straggler_prob": 0.2, "straggler_overprovision": 1.25}
    if mode == "async":
        tp.update({"mode": "async", "async_buffer": 3, "max_staleness": 4,
                   "staleness_exponent": 0.5})
        runtime = {"straggler_prob": 0.2, "duration_sigma": 0.25}
    tp.update(tp_extra)
    raw = {
        "name": "probe-test",
        "model": {"arch": "flsim-mlp"},
        "dataset": {"dataset": "synthetic_vision", "n_items": 128,
                    "distribution": {"partition": "dirichlet",
                                     "dirichlet_alpha": 0.5}},
        "strategy": {"strategy": strategy, "train_params": tp},
        "runtime": runtime,
    }
    for key, val in (("sweep", sweep), ("probes", probes),
                     ("telemetry", telemetry)):
        if val is not None:
            raw[key] = val
    return raw


def _params(state):
    return jax.tree.map(np.asarray, state["params"])


def _assert_bitwise_equal(p1, p2):
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _run(raw):
    ex = Executor(load_job(raw)).scaffold()
    state, _ = ex.run()
    return ex, state


# ---------------------------------------------------------------------------
# bitwise invariance: probes only consume, never perturb
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["sync", "async"])
def test_bitwise_probes_on_vs_off(mode):
    ex_on, s_on = _run(_raw(mode=mode, probes=_PROBES_ON))
    _, s_off = _run(_raw(mode=mode))
    _assert_bitwise_equal(_params(s_off), _params(s_on))
    assert len(ex_on.probe_rows) == 4


def test_bitwise_temporal_placement():
    ex_on, s_on = _run(_raw(probes=_PROBES_ON, placement="temporal"))
    _, s_off = _run(_raw(placement="temporal"))
    _assert_bitwise_equal(_params(s_off), _params(s_on))
    assert all(r["participation"] > 0 for r in ex_on.probe_rows)


def test_bitwise_int8_and_quant_probes():
    kw = dict(strategy="compressed", compression="int8",
              error_feedback=True)
    ex_on, s_on = _run(_raw(probes=_PROBES_ON, **kw))
    _, s_off = _run(_raw(**kw))
    _assert_bitwise_equal(_params(s_off), _params(s_on))
    assert any(row["sat_frac"] > 0.0 for row in ex_on.probe_rows)
    for row in ex_on.probe_rows:
        assert 0.0 <= row["sat_frac"] <= 1.0
    # error feedback is on by default: residual mass accumulates after
    # round 0, so the probe must be a live (nonzero) signal
    assert ex_on.probe_rows[-1]["ef_residual_norm"] > 0.0


def test_campaign_bitwise_probes_on_vs_off():
    sweep = {"seeds": [3, 5]}
    c_off = CampaignExecutor(load_job(_raw(sweep=sweep))).scaffold()
    c_off.run()
    c_on = CampaignExecutor(load_job(
        _raw(sweep=sweep, probes=_PROBES_ON))).scaffold()
    c_on.run()
    for s in range(2):
        _assert_bitwise_equal(c_off.trajectory_params(s),
                              c_on.trajectory_params(s))
    # one row per (lane, round), keyed by sweep coords like campaign.csv
    assert len(c_on.probe_rows) == 2 * 4
    assert {r["seed"] for r in c_on.probe_rows} == {3, 5}


# ---------------------------------------------------------------------------
# probe values: schema, determinism across chunkings
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["sync", "async"])
def test_probe_values_chunking_invariant(mode):
    ex1, _ = _run(_raw(mode=mode, chunk=1, probes=_PROBES_ON))
    ex4, _ = _run(_raw(mode=mode, chunk=4, probes=_PROBES_ON))
    assert ex1.probe_rows == ex4.probe_rows


def test_probe_row_schema():
    ex, _ = _run(_raw(probes=_PROBES_ON))
    for i, row in enumerate(ex.probe_rows):
        assert row["round"] == i
        assert set(PROBE_NAMES) <= set(row)
        assert 0 < row["participation"] <= 4
        assert 0.0 <= row["masked_frac"] <= 1.0
        assert row["update_norm"] > 0.0
        assert row["nonfinite"] == 0.0


# ---------------------------------------------------------------------------
# divergence sentinel: report fires, freeze holds the last finite state
# ---------------------------------------------------------------------------

def _finite(state):
    return all(np.isfinite(np.asarray(leaf)).all()
               for leaf in jax.tree.leaves(state["params"]))


def test_divergence_sentinel_reports():
    ex, state = _run(_raw(probes=_PROBES_ON, client_lr=1e8))
    nf = [r["nonfinite"] for r in ex.probe_rows]
    assert nf[0] == 0.0 and 1.0 in nf
    assert not _finite(state)           # report mode does not intervene


def test_divergence_freeze_holds_finite_state():
    ex, state = _run(_raw(client_lr=1e8, probes={
        "enabled": True, "on_divergence": "freeze"}))
    assert any(r["nonfinite"] == 1.0 for r in ex.probe_rows)
    assert _finite(state)               # frozen at the last finite params


def test_freeze_is_bitwise_noop_without_divergence():
    _, s_frz = _run(_raw(probes={"enabled": True,
                                 "on_divergence": "freeze"}))
    _, s_off = _run(_raw())
    _assert_bitwise_equal(_params(s_off), _params(s_frz))


# ---------------------------------------------------------------------------
# drain plumbing: probes.csv, counter tracks, Perfetto export
# ---------------------------------------------------------------------------

def test_probes_csv_and_counter_tracks(tmp_path):
    ex, _ = _run(_raw(probes=_PROBES_ON,
                      telemetry={"out_dir": str(tmp_path)}))
    ex.recorder.close()
    rows = read_probes(tmp_path / "probes.csv")
    assert len(rows) == 4
    assert rows == ex.probe_rows         # csv round-trips the full buffer
    counters = {e["name"] for e in ex.recorder.events
                if e.get("kind") == "counter"}
    assert {f"probe:{n}" for n in PROBE_NAMES} <= counters
    spans = {e["name"] for e in ex.recorder.events if e["kind"] == "span"}
    assert "probe_flush" in spans
    # counter samples are back-dated inside their launch span
    launch = next(e for e in ex.recorder.events if e.get("name") == "launch")
    sample = next(e for e in ex.recorder.events
                  if e.get("name") == "probe:update_norm")
    assert launch["t0_us"] <= sample["t_us"] \
        <= launch["t0_us"] + launch["dur_us"]
    # Perfetto export renders them as "C" counter events
    tr = to_chrome_trace(read_events(tmp_path))
    cs = [e for e in tr["traceEvents"]
          if e["ph"] == "C" and e["name"] == "probe:update_norm"]
    assert cs and all("value" in e["args"] for e in cs)


def test_campaign_per_lane_counters_and_csv(tmp_path):
    c = CampaignExecutor(load_job(_raw(
        sweep={"seeds": [3, 5]},
        telemetry={"out_dir": str(tmp_path)},
        probes={"enabled": True, "out_dir": str(tmp_path)}))).scaffold()
    c.run()
    sample = next(e for e in c.recorder.events
                  if e.get("name") == "probe:update_norm")
    assert set(sample["values"]) == {"lane0", "lane1"}
    rows = read_probes(tmp_path / "probes.csv")
    assert len(rows) == 8
    assert {(r["seed"], r["traj"]) for r in rows} == {(3, 0), (5, 1)}
    assert all(set(PROBE_NAMES) <= set(r) for r in rows)


def test_async_staleness_hist_and_occupancy(tmp_path):
    ex, _ = _run(_raw(mode="async", probes=_PROBES_ON,
                      telemetry={"out_dir": str(tmp_path)}))
    hist = next(e for e in ex.recorder.events
                if e.get("name") == "probe:staleness_hist")
    assert sum(hist["values"].values()) > 0
    assert all(k.startswith("s") for k in hist["values"])
    assert all(0.0 <= r["buffer_occ"] <= ex.job.fl.async_buffer
               for r in ex.probe_rows)


def test_probes_memory_only_without_out_dir():
    ex, _ = _run(_raw(probes=_PROBES_ON))
    assert ex._probe_path() is None and len(ex.probe_rows) == 4


# ---------------------------------------------------------------------------
# helpers: occupancy / histogram host math
# ---------------------------------------------------------------------------

def test_buffer_occupancy_resets_on_apply():
    occ = buffer_occupancy(np.array([1, 1, 0, 1, 1, 1]),
                           np.array([0, 0, 0, 1, 0, 0]))
    assert occ.tolist() == [1, 2, 2, 0, 1, 2]


def test_staleness_hist_clips_to_max():
    h = staleness_hist(np.array([0, 0, 1, 7, 9]), max_staleness=4)
    assert h == {"s0": 2, "s1": 1, "s2": 0, "s3": 0, "s4": 2}


# ---------------------------------------------------------------------------
# program cost attribution (tentpole rider) + digest cadence (carried item)
# ---------------------------------------------------------------------------

def test_program_cost_recorded_on_compile_launch(tmp_path):
    ex, _ = _run(_raw(telemetry={"out_dir": str(tmp_path)}))
    cost = [e for e in ex.recorder.events
            if e.get("name") == "program_cost"]
    assert len(cost) == 1                # once per compiled program
    assert cost[0]["values"]["flops"] > 0
    assert cost[0]["values"]["bytes_accessed"] > 0
    text = report([dict(e) for e in ex.recorder.events])
    assert "gflops" in text and "GB" in text


def test_program_cost_opt_out(tmp_path):
    ex, _ = _run(_raw(telemetry={"out_dir": str(tmp_path),
                                 "cost_analysis": False}))
    assert not any(e.get("name") == "program_cost"
                   for e in ex.recorder.events)


def test_digest_cadence_chunking_invariant():
    blocks = {}
    for chunk in (1, 4):
        raw = _raw(mode="async", chunk=chunk, digest_every_events=5)
        raw["consensus"] = {"blockchain": "hashchain"}
        ex, _ = _run(raw)
        digests = [b for b in ex.job.ledger.blocks()
                   if b.kind == "async_digest"]
        # 4 rounds x 3 events/round = 12 events -> marks at 5, 10
        assert [b.payload["event"] for b in digests] == [5, 10]
        blocks[chunk] = len(digests)
        assert ex._digest_blocks == len(digests)
    assert blocks[1] == blocks[4] == 2


def test_digest_cadence_span_and_counter(tmp_path):
    raw = _raw(mode="async", digest_every_events=5,
               telemetry={"out_dir": str(tmp_path)})
    raw["consensus"] = {"blockchain": "hashchain"}
    ex, _ = _run(raw)
    spans = [e for e in ex.recorder.events
             if e["kind"] == "span" and e["name"] == "digest"]
    assert spans and sum(e["attrs"]["blocks"] for e in spans) == 2
    ctr = [e for e in ex.recorder.events
           if e.get("kind") == "counter" and e["name"] == "digest"]
    assert ctr[-1]["values"]["blocks"] == 2


# ---------------------------------------------------------------------------
# job-loader validation of the probes: section
# ---------------------------------------------------------------------------

def test_probes_section_unknown_key():
    with pytest.raises(KeyError, match="on_divergence"):
        load_job(_raw(probes={"on_divergenc": "freeze"}))


def test_probes_section_bad_on_divergence():
    with pytest.raises(ValueError, match="report"):
        load_job(_raw(probes={"enabled": True, "on_divergence": "halt"}))


def test_probes_freeze_requires_enabled():
    with pytest.raises(ValueError, match="enabled"):
        load_job(_raw(probes={"enabled": False, "on_divergence": "freeze"}))


def test_probe_spec_defaults_off():
    spec = ProbeSpec.from_job(load_job(_raw()))
    assert not spec.enabled and not spec.freeze


# ---------------------------------------------------------------------------
# satellite: trace-report self-time edge cases
# ---------------------------------------------------------------------------

def _span(id, name, t0, dur, parent=None, track="run", **attrs):
    return {"kind": "span", "id": id, "parent": parent, "depth": 0,
            "name": name, "track": track, "t0_us": t0, "dur_us": dur,
            "attrs": attrs}


def test_report_zero_duration_spans():
    text = report([_span(1, "launch", 0, 0, compile_delta=1),
                   _span(2, "eval", 0, 0)])
    assert "compile" in text and "nan" not in text and "-0" not in text


def test_report_children_exceeding_parent_clamp():
    # child longer than its parent (clock skew): self time clamps to >= 0
    # instead of subtracting a negative share from the category totals
    text = report([_span(1, "chunk", 0, 10),
                   _span(2, "launch", 0, 50, parent=1, compile_delta=0)])
    host = next(line for line in text.splitlines()
                if line.strip().startswith("host"))
    assert " 0.000 " in host


def test_report_counters_but_no_launches():
    events = [_span(1, "eval", 0, 100),
              {"kind": "counter", "name": "program_cost", "track": "run",
               "t_us": 50, "values": {"flops": 1e9, "bytes_accessed": 1e8}}]
    text = report(events)                # track row skipped, no crash
    assert "io" in text and "launches" in text


# ---------------------------------------------------------------------------
# satellite: read_events tolerance of empty / torn telemetry.jsonl
# ---------------------------------------------------------------------------

def _jsonl(tmp_path, text):
    (tmp_path / "telemetry.jsonl").write_text(text)
    return tmp_path


def test_read_events_empty_file_names_path(tmp_path):
    with pytest.raises(ValueError, match="telemetry.jsonl"):
        read_events(_jsonl(tmp_path, ""))


def test_read_events_tolerates_torn_tail(tmp_path):
    good = json.dumps({"kind": "meta", "run": "r"})
    events = read_events(_jsonl(tmp_path, good + '\n{"kind": "sp'))
    assert len(events) == 1 and events[0]["run"] == "r"


def test_read_events_only_torn_line_raises(tmp_path):
    with pytest.raises(ValueError, match="telemetry.jsonl"):
        read_events(_jsonl(tmp_path, '{"kind": "sp'))


def test_read_events_mid_file_corruption_raises(tmp_path):
    good = json.dumps({"kind": "meta", "run": "r"})
    with pytest.raises(ValueError, match="line 2"):
        read_events(_jsonl(tmp_path, good + "\nnot json\n" + good + "\n"))
