"""MoE dispatch correctness + MLA absorbed/expanded algebraic identity."""
import os

os.environ.setdefault("REPRO_KERNEL_IMPL", "jnp")

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig
from repro.models import moe as moe_mod
from repro.models import attention as attn_mod
from repro.sharding.axes import AxisCtx

CTX = AxisCtx()


def moe_cfg(ep_mode="model", E=8, k=2, f_sub=1, cf=8.0):
    return ModelConfig(
        name="t", family="moe", n_layers=1, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=16, vocab_size=64,
        moe=MoEConfig(n_experts=E, top_k=k, expert_d_ff=16,
                      capacity_factor=cf, ep_mode=ep_mode, f_sub=f_sub,
                      load_balance_loss=0.0, router_z_loss=0.0))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([1, 2, 4]))
def test_moe_capacity_dispatch_matches_dense_ref(seed, k):
    """With generous capacity (no drops) the bucketed dispatch must equal the
    dense masked reference exactly."""
    cfg = moe_cfg(k=k)
    key = jax.random.PRNGKey(seed)
    w = moe_mod.init_moe_params(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model))
    got, aux = moe_mod.moe_ffn(CTX, w, x, cfg)
    want = moe_mod.moe_ffn_dense_ref(w, x, cfg)
    assert float(aux.drop_fraction) == 0.0
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_moe_subgrid_single_device_matches_dense_ref():
    cfg = moe_cfg(ep_mode="subgrid", E=4, f_sub=2)
    key = jax.random.PRNGKey(0)
    w = moe_mod.init_moe_params(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model))
    got, aux = moe_mod.moe_ffn(CTX, w, x, cfg)
    # reference: unpack (E*fs, D, F/fs) -> (E, D, F) and run dense ref
    E, fs, F = 4, 2, cfg.moe.expert_d_ff
    D = cfg.d_model
    w_full = {
        "router": w["router"],
        "w1": jnp.moveaxis(w["w1"].reshape(E, fs, D, F // fs), 1, 2)
        .reshape(E, D, F),
        "w3": jnp.moveaxis(w["w3"].reshape(E, fs, D, F // fs), 1, 2)
        .reshape(E, D, F),
        "w2": w["w2"].reshape(E, fs, F // fs, D).reshape(E, F, D),
    }
    want = moe_mod.moe_ffn_dense_ref(w_full, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_moe_capacity_drops_are_reported():
    cfg = moe_cfg(cf=0.25)   # force drops
    key = jax.random.PRNGKey(0)
    w = moe_mod.init_moe_params(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 64, cfg.d_model))
    _, aux = moe_mod.moe_ffn(CTX, w, x, cfg)
    assert float(aux.drop_fraction) > 0.0


# ---------------------------------------------------------------------------
# MLA: absorbed == expanded (exact algebraic identity)
# ---------------------------------------------------------------------------

def mla_cfg():
    return ModelConfig(
        name="t", family="dense", n_layers=1, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=64, vocab_size=64, attn_type="mla",
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=8,
                      qk_rope_head_dim=8, v_head_dim=8))


def test_mla_absorbed_equals_expanded():
    cfg = mla_cfg()
    key = jax.random.PRNGKey(0)
    w = attn_mod.init_attn_params(key, cfg)
    h = jax.random.normal(jax.random.fold_in(key, 1), (2, 32, cfg.d_model))
    os.environ["REPRO_MLA_ABSORBED"] = "1"
    absorbed = attn_mod.mla_seqsharded(CTX, w, h, cfg)
    os.environ["REPRO_MLA_ABSORBED"] = "0"
    expanded = attn_mod.mla_seqsharded(CTX, w, h, cfg)
    os.environ.pop("REPRO_MLA_ABSORBED")
    np.testing.assert_allclose(np.asarray(absorbed, np.float32),
                               np.asarray(expanded, np.float32),
                               atol=2e-4, rtol=2e-4)


def test_mla_decode_matches_prefill_tail():
    """Absorbed decode over a latent cache == last position of full forward."""
    cfg = mla_cfg()
    key = jax.random.PRNGKey(1)
    w = attn_mod.init_attn_params(key, cfg)
    S = 16
    h = jax.random.normal(jax.random.fold_in(key, 1), (2, S + 1, cfg.d_model))
    full = attn_mod.mla_seqsharded(CTX, w, h, cfg)
    _, cache = attn_mod.mla_seqsharded(CTX, w, h[:, :S], cfg,
                                       return_cache=True)
    # grow cache by one slot and decode the last token
    cache = attn_mod.LatentCache(
        jnp.pad(cache.ckv, ((0, 0), (0, 1), (0, 0))),
        jnp.pad(cache.krope, ((0, 0), (0, 1), (0, 0))))
    length = jnp.full((2,), S, jnp.int32)
    out, _ = attn_mod.mla_decode(CTX, w, h[:, S:S + 1], cache, length, cfg)
    np.testing.assert_allclose(np.asarray(out[:, 0], np.float32),
                               np.asarray(full[:, S], np.float32),
                               atol=2e-4, rtol=2e-4)
