"""Sharded-vs-single-device equivalence — executed in a subprocess with
forced host devices (imported by test_sharded_equivalence.py).

For each reduced arch: the shard_map'd train step (loss value) and decode
step (logits) must match the meshless oracle to fp tolerance. This validates
the gather tables, sequence-sharded attention offsets, EP dispatch + ring,
the embedding layouts, the distributed softmax and the LSE decode combine.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("REPRO_KERNEL_IMPL", "jnp")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ShapeConfig, get_config
from repro.configs.reduce import reduced_config
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_test_mesh, mesh_context
from repro.models import model_zoo
from repro.sharding.axes import AxisCtx

MESHES = {
    "dm": make_test_mesh((2, 2), ("data", "model")),
    "pdm": make_test_mesh((2, 2, 2), ("pod", "data", "model")),
}


def reduced(arch):
    cfg = reduced_config(get_config(arch))
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=4.0))
    return cfg


def materialize(structs, seed=0):
    """Random global arrays matching the ShapeDtypeStruct tree (+sharding)."""
    leaves, treedef = jax.tree_util.tree_flatten(structs)
    rng = np.random.RandomState(seed)
    out = []
    for i, s in enumerate(leaves):
        if np.issubdtype(s.dtype, np.integer):
            a = rng.randint(0, 2, size=s.shape).astype(s.dtype)
        else:
            a = (rng.randn(*s.shape) * 0.02).astype(s.dtype)
        out.append(jax.device_put(jnp.asarray(a), s.sharding))
    return jax.tree_util.tree_unflatten(treedef, out)


def check_train(arch, mesh_name, B=8, S=32):
    cfg = reduced(arch)
    mesh = MESHES[mesh_name]
    shape = ShapeConfig("t", S, B, "train")
    built = steps_mod.make_train_step(cfg, shape, mesh)
    # materialize inputs; tokens within vocab
    state, batch, weights, rng = materialize(built.inputs)
    batch = jax.tree.map(
        lambda t: (t % cfg.vocab_size) if t.dtype == jnp.int32 else t, batch)
    weights = jnp.ones_like(weights)
    rng = jnp.zeros((2,), jnp.uint32)
    with mesh_context(mesh):
        new_state, metrics = jax.jit(built.fn)(state, batch, weights, rng)
        sharded_loss = float(metrics["loss"])
        sharded_params = jax.tree.map(np.asarray, new_state["params"])

    # oracle: same semantics meshless
    from repro.core.rounds import build_spatial_round, build_temporal_round
    from repro.core.strategies import get_strategy
    from repro.configs.base import FLConfig
    from repro.sharding import specs as sspecs
    fl = FLConfig(strategy="fedavg", local_epochs=1, client_lr=1e-2)
    model = model_zoo.build(cfg)
    strategy = get_strategy(fl)
    ctx0 = AxisCtx()
    params_full = jax.tree.map(np.asarray, state["params"])
    state0 = {"params": jax.tree.map(jnp.asarray, params_full),
              "server": (), "clients": ()}
    spatial = sspecs.placement_for(cfg) == "spatial"
    if spatial:
        rf = build_spatial_round(model, strategy, fl)
        # flatten client grid into leading dim
        b0 = jax.tree.map(lambda t: jnp.asarray(np.asarray(t)), batch)
        w0 = jnp.asarray(np.asarray(weights))
        st, m = jax.jit(lambda s, b, w, r: rf(ctx0, s, b, w, r))(
            state0, b0, w0, rng)
    else:
        rf = build_temporal_round(model, strategy, fl, cfg)
        b0 = jax.tree.map(lambda t: jnp.asarray(np.asarray(t)), batch)
        st, m = jax.jit(lambda s, b, w, r: rf(ctx0, s, b, w, r))(
            state0, b0, jnp.asarray(np.asarray(weights)), rng)
    oracle_loss = float(m["loss"])
    ok_loss = abs(sharded_loss - oracle_loss) < 5e-2 * max(1, abs(oracle_loss))
    # parameter agreement (sampled leaves)
    o_params = jax.tree.map(np.asarray, st["params"])
    errs = []
    for a, b in zip(jax.tree.leaves(sharded_params),
                    jax.tree.leaves(o_params)):
        d = np.max(np.abs(a.astype(np.float32) - b.astype(np.float32)))
        errs.append(d)
    ok_params = max(errs) < 5e-2
    status = "OK" if (ok_loss and ok_params) else "MISMATCH"
    print(f"TRAIN {arch:24s} {mesh_name:3s} loss {sharded_loss:+.5f} vs "
          f"{oracle_loss:+.5f}  max_param_err {max(errs):.2e}  {status}")
    return ok_loss and ok_params


def check_decode(arch, mesh_name, B=8, S=32):
    cfg = reduced(arch)
    mesh = MESHES[mesh_name]
    shape = ShapeConfig("d", S, B, "decode")
    built = steps_mod.make_decode_step(cfg, shape, mesh)
    params, tokens, caches, length = materialize(built.inputs)
    tokens = tokens % cfg.vocab_size
    length = jnp.full_like(length, S - 1)
    with mesh_context(mesh):
        logits, _ = jax.jit(built.fn)(params, tokens, caches, length)
        logits_sh = np.asarray(logits).astype(np.float32)

    model = model_zoo.build(cfg)
    ctx0 = AxisCtx()
    p0 = jax.tree.map(lambda t: jnp.asarray(np.asarray(t)), params)
    c0 = jax.tree.map(lambda t: jnp.asarray(np.asarray(t)), caches)
    t0 = jnp.asarray(np.asarray(tokens))
    l0 = jnp.asarray(np.asarray(length))
    lo, _ = jax.jit(lambda p, t, c, ln: model.decode_step(
        ctx0, p, t, c, ln, tp=False))(p0, t0, c0, l0)
    logits_or = np.asarray(lo).astype(np.float32)
    err = np.max(np.abs(logits_sh - logits_or))
    scale = np.maximum(np.max(np.abs(logits_or)), 1e-3)
    ok = err < 5e-2 * scale
    print(f"DECODE {arch:23s} {mesh_name:3s} max_err {err:.2e} "
          f"(scale {scale:.2e})  {'OK' if ok else 'MISMATCH'}")
    return ok


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    archs_train = ["yi-34b", "minicpm3-4b", "qwen3-moe-30b-a3b",
                   "arctic-480b", "jamba-1.5-large-398b", "whisper-base",
                   "xlstm-125m"]
    archs_decode = ["yi-34b", "minicpm3-4b", "qwen3-moe-30b-a3b",
                    "jamba-1.5-large-398b", "whisper-base", "xlstm-125m"]
    ok = True
    for arch in archs_train:
        if which in ("all", "train", arch):
            for mesh_name in ("dm", "pdm"):
                ok &= check_train(arch, mesh_name)
    for arch in archs_decode:
        if which in ("all", "decode", arch):
            ok &= check_decode(arch, "dm")
    print("ALL OK" if ok else "FAILURES PRESENT")
    sys.exit(0 if ok else 1)
