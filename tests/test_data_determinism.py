"""Dataset distribution (paper component 3) + reproducibility (RQ6) tests."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="partition property tests need hypothesis")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig, get_config
from repro.core import determinism
from repro.core.rounds import build_spatial_round, init_state
from repro.core.strategies import get_strategy
from repro.data import partition as pmod
from repro.data.pipeline import SyntheticLM, SyntheticVision
from repro.models import model_zoo
from repro.sharding.axes import AxisCtx


# ---------------------------------------------------------------------------
# partitioners
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(2, 16), st.sampled_from(["dirichlet", "iid", "shards"]),
       st.integers(0, 10_000))
def test_partition_conservation_and_disjoint(n_clients, kind, seed):
    rng = np.random.RandomState(seed)
    labels = rng.randint(0, 10, 600)
    parts = pmod.partition(kind, labels, n_clients, alpha=0.5, seed=seed)
    allidx = np.concatenate(parts)
    assert len(allidx) == len(labels)
    assert len(np.unique(allidx)) == len(labels)   # disjoint cover


def test_partition_deterministic():
    labels = np.random.RandomState(0).randint(0, 10, 500)
    a = pmod.partition("dirichlet", labels, 8, 0.5, seed=42)
    b = pmod.partition("dirichlet", labels, 8, 0.5, seed=42)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_dirichlet_alpha_controls_heterogeneity():
    labels = np.random.RandomState(0).randint(0, 10, 4000)
    h_small = pmod.heterogeneity(
        pmod.partition("dirichlet", labels, 10, 0.1, 0), labels)
    h_big = pmod.heterogeneity(
        pmod.partition("dirichlet", labels, 10, 100.0, 0), labels)
    h_iid = pmod.heterogeneity(pmod.partition("iid", labels, 10), labels)
    assert h_small > h_big > 0
    assert h_iid < 0.2
    assert h_small > 3 * h_iid


# ---------------------------------------------------------------------------
# reproducibility (paper Tables 1-2: same seed -> bitwise identical)
# ---------------------------------------------------------------------------

def _run_two_rounds(seed):
    fl = FLConfig(strategy="fedavg", n_clients=4, local_epochs=1,
                  client_lr=0.1, seed=seed)
    model = model_zoo.build(get_config("flsim-mlp"))
    strategy = get_strategy(fl)
    round_fn = jax.jit(lambda s, b, w, r: build_spatial_round(
        model, strategy, fl)(AxisCtx(), s, b, w, r))
    data = SyntheticVision(n_items=256, seed=seed)
    x, y, parts = data.distribute_into_chunks("dirichlet", fl.n_clients, 0.5)
    state = init_state(model, strategy, fl, determinism.root_key(seed),
                       n_clients_local=fl.n_clients)
    losses = []
    for r in range(2):
        bs = [SyntheticVision.client_batches(x, y, parts[c], 16, 1,
                                             seed=c + r * 31)[0]
              for c in range(fl.n_clients)]
        batch = jax.tree.map(lambda *t: np.stack(t), *bs)
        w = jnp.ones((fl.n_clients,), jnp.float32)
        state, m = round_fn(state, batch, w,
                            determinism.round_key(
                                determinism.root_key(seed), r))
        losses.append(float(m["loss"]))
    return losses, jax.tree.map(np.asarray, state["params"])


def test_bitwise_reproducibility():
    l1, p1 = _run_two_rounds(7)
    l2, p2 = _run_two_rounds(7)
    assert l1 == l2, "losses must be bitwise identical across trials"
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(a, b)


def test_seed_changes_trajectory():
    l1, _ = _run_two_rounds(7)
    l2, _ = _run_two_rounds(8)
    assert l1 != l2


# ---------------------------------------------------------------------------
# LM pipeline
# ---------------------------------------------------------------------------

def test_lm_stream_learnable_structure():
    lm = SyntheticLM(vocab=64, seed=0)
    b = lm.tokens(8, 128)
    # 75% of transitions follow the permutation: measure empirically
    t, l = np.asarray(b["tokens"]), np.asarray(b["labels"])
    # build empirical transition argmax
    follows = 0
    trans = {}
    for i in range(t.shape[0]):
        for j in range(t.shape[1]):
            trans.setdefault(t[i, j], {}).setdefault(l[i, j], 0)
            trans[t[i, j]][l[i, j]] += 1
    top = sum(max(v.values()) for v in trans.values())
    total = t.size
    assert top / total > 0.55, "stream should have learnable structure"


def test_lm_client_batches_deterministic():
    lm = SyntheticLM(vocab=64, seed=0)
    a = lm.client_batches(3, 2, 4, 32, round_idx=1)
    b = lm.client_batches(3, 2, 4, 32, round_idx=1)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = lm.client_batches(4, 2, 4, 32, round_idx=1)
    assert not np.array_equal(a["tokens"], c["tokens"])
