"""Event-driven async FL subsystem tests (virtual clock + FedAsync/FedBuff).

The async determinism contract mirrors the sync driver's
(tests/test_driver.py): for one seed the event trajectory is bitwise
identical no matter how events are chunked into launches, and the virtual
clock schedule is a pure function of the seed. The anchor is the identity
test: FedBuff with buffer == cohort, zero staleness discount and equal
client speeds IS synchronous FedAvg, bit for bit.
"""
import os

os.environ.setdefault("REPRO_KERNEL_IMPL", "jnp")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.jobs import load_job
from repro.runtime.clock import ClientSystemModel, build_schedule
from repro.runtime.executor import Executor


def _job(rounds_per_launch: int, rounds: int = 4, seed: int = 7, *,
         mode: str = "async", async_buffer: int = 3,
         staleness_exponent: float = 0.5, max_staleness: int = 4,
         placement: str = "spatial", runtime=None, n_clients: int = 4,
         **train_extra):
    raw = {
        "name": f"async-{mode}-{rounds_per_launch}",
        "model": {"arch": "flsim-mlp"},
        "dataset": {"dataset": "synthetic_vision", "n_items": 256,
                    "distribution": {"partition": "dirichlet",
                                     "dirichlet_alpha": 0.5}},
        "strategy": {"strategy": "fedavg",
                     "train_params": {"n_clients": n_clients,
                                      "local_epochs": 1,
                                      "client_lr": 0.1, "rounds": rounds,
                                      "seed": seed, "mode": mode,
                                      "placement": placement,
                                      "async_buffer": async_buffer,
                                      "staleness_exponent":
                                          staleness_exponent,
                                      "max_staleness": max_staleness,
                                      "rounds_per_launch":
                                          rounds_per_launch}},
        "runtime": runtime if runtime is not None else
                   {"straggler_prob": 0.2, "duration_sigma": 0.25},
    }
    raw["strategy"]["train_params"].update(train_extra)
    return load_job(raw)


def _params(state):
    return jax.tree.map(np.asarray, state["params"])


def _assert_bitwise_equal(p1, p2):
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(a, b)


EQUAL_SPEEDS = {"straggler_prob": 0.0, "duration_sigma": 0.0,
                "rate_spread": 0.0, "availability": 1.0}


# ---------------------------------------------------------------------------
# determinism contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("async_buffer", [3, 0])  # FedBuff(3) and FedAsync
def test_event_scan_chunked_equals_unchunked(async_buffer):
    """One fused event scan (rounds_per_launch=10) == per-chunk launches
    (=1), bitwise, under real heterogeneity (stragglers + jitter + staleness
    discount); an uneven chunking (3+1) must also agree."""
    runs = {}
    for chunk in (1, 10, 3):
        ex = Executor(_job(chunk, async_buffer=async_buffer)).scaffold()
        state, logger = ex.run()
        runs[chunk] = (_params(state), logger.series("loss"))
    assert runs[1][1] == runs[10][1], "per-round async losses diverged"
    _assert_bitwise_equal(runs[1][0], runs[10][0])
    _assert_bitwise_equal(runs[1][0], runs[3][0])


def test_fedbuff_identity_with_sync_fedavg():
    """FedBuff with buffer == cohort, zero staleness discount and equal
    client speeds reproduces synchronous FedAvg (temporal placement)
    bit-for-bit: same arrivals in client order per round, same batch keys,
    same sequential weighted accumulation, same server update."""
    sync = Executor(_job(5, rounds=5, seed=11, mode="sync",
                         placement="temporal",
                         runtime=EQUAL_SPEEDS)).scaffold()
    s_sync, _ = sync.run()
    asy = Executor(_job(5, rounds=5, seed=11, async_buffer=4,
                        staleness_exponent=0.0,
                        runtime=EQUAL_SPEEDS)).scaffold()
    s_async, _ = asy.run()
    _assert_bitwise_equal(_params(s_sync), _params(s_async))
    # all arrivals fresh: every event has staleness 0 and every round applies
    assert all(s == 0.0 for s in asy.logger.series("staleness"))
    assert all(a == 1.0 for a in asy.logger.series("applied"))


def test_async_trains():
    """Under heterogeneity the async run must still learn (loss falls) and
    report non-trivial staleness."""
    ex = Executor(_job(10, rounds=6, async_buffer=2)).scaffold()
    _, logger = ex.run()
    losses = logger.series("loss")
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]
    assert max(logger.series("staleness")) > 0.0


# ---------------------------------------------------------------------------
# virtual clock / schedule
# ---------------------------------------------------------------------------

def test_schedule_deterministic_and_staleness_bounded():
    csm = ClientSystemModel(seed=3, straggler_prob=0.3, duration_sigma=0.5,
                            rate_spread=0.5, availability=0.9)
    w = np.asarray([4.0, 1.0, 2.0, 8.0, 5.0], np.float32)
    kw = dict(buffer_size=3, staleness_exponent=0.5, max_staleness=2)
    s1 = build_schedule(csm, 5, 40, w, **kw)
    s2 = build_schedule(csm, 5, 40, w, **kw)
    for f in ("client", "task", "staleness", "accept", "apply", "coeff",
              "read_slot", "write_slot", "vtime"):
        np.testing.assert_array_equal(getattr(s1, f), getattr(s2, f))
    # arrivals are virtual-time ordered; accepted ones respect max_staleness
    assert (np.diff(s1.vtime) >= 0).all()
    assert (s1.staleness[s1.accept] <= 2).all()
    assert (s1.coeff[~s1.accept] == 0.0).all()
    # heterogeneity actually produced stale arrivals
    assert s1.staleness.max() > 0
    # FedBuff: one apply per 3 accepted arrivals
    assert s1.apply.sum() == s1.accept.sum() // 3 == s1.n_versions


def test_schedule_prefix_stable():
    """Extending the horizon must not rewrite history: the first E events of
    a longer schedule equal the E-event schedule (apply/coeff of a trailing
    open buffer group are the only allowed difference, and the executor
    never applies an open group)."""
    csm = ClientSystemModel(seed=1, straggler_prob=0.2, duration_sigma=0.3)
    w = np.ones(4, np.float32)
    kw = dict(buffer_size=3, staleness_exponent=0.5, max_staleness=4)
    short = build_schedule(csm, 4, 12, w, **kw)
    long = build_schedule(csm, 4, 24, w, **kw)
    last_apply = int(np.nonzero(short.apply)[0][-1]) + 1
    for f in ("client", "task", "staleness", "accept", "read_slot", "vtime"):
        np.testing.assert_array_equal(getattr(short, f),
                                      getattr(long, f)[:12])
    np.testing.assert_array_equal(short.apply[:last_apply],
                                  long.apply[:last_apply])
    np.testing.assert_array_equal(short.coeff[:last_apply],
                                  long.coeff[:last_apply])


def test_equal_speed_schedule_is_round_robin():
    """Equal speeds + buffer == cohort: arrivals land in client order with
    zero staleness and one apply per cohort — the schedule shape behind the
    sync-identity test."""
    csm = ClientSystemModel(seed=0, straggler_prob=0.0, duration_sigma=0.0,
                            rate_spread=0.0)
    s = build_schedule(csm, 3, 9, np.ones(3, np.float32), buffer_size=3,
                       staleness_exponent=0.0, max_staleness=8)
    np.testing.assert_array_equal(s.client, np.tile(np.arange(3), 3))
    np.testing.assert_array_equal(s.task, np.repeat(np.arange(3), 3))
    assert (s.staleness == 0).all() and s.accept.all()
    np.testing.assert_array_equal(np.nonzero(s.apply)[0], [2, 5, 8])
    np.testing.assert_allclose(s.coeff, np.full(9, 1 / 3, np.float32))


def test_schedule_single_client():
    """Degenerate cohort: one client completing every task must schedule
    cleanly (regression: the re-dispatch after the last event used to index
    past a fixed-size duration matrix)."""
    csm = ClientSystemModel(seed=0, duration_sigma=0.1)
    s = build_schedule(csm, 1, 6, np.ones(1, np.float32), buffer_size=0)
    np.testing.assert_array_equal(s.client, np.zeros(6))
    np.testing.assert_array_equal(s.task, np.arange(6))
    assert s.accept.all() and s.apply.all()


def test_gather_one_client_matches_vmapped_gather():
    """The async per-event gather must be bitwise lane `c` of the sync
    driver's vmapped gather (threefry vectorization invariance)."""
    from repro.core import determinism
    from repro.data.pipeline import (SyntheticVision, gather_client_batches,
                                     gather_one_client_batch,
                                     stage_partitions)
    data = SyntheticVision(n_items=128, seed=0)
    x, y, parts = data.distribute_into_chunks("dirichlet", 4, 0.5)
    staged = stage_partitions(x, y, parts)
    rkey = determinism.round_key(determinism.root_key(0), 2)
    all_batches = gather_client_batches(staged, rkey, 8, 2)
    for c in range(4):
        one = gather_one_client_batch(staged, jnp.asarray(rkey), c, 8, 2)
        for k in ("x", "y"):
            np.testing.assert_array_equal(np.asarray(all_batches[k][c]),
                                          np.asarray(one[k]))


def test_async_checkpoint_resume(tmp_path):
    """Async runs reuse the checkpoint plumbing: stopping after a chunk and
    resuming from the manifest continues the same bitwise trajectory
    (the schedule is re-derived from the seed, the ring/accumulator carries
    are restored from the checkpoint)."""
    def mk():
        return _job(2, rounds=4, async_buffer=2, checkpoint_every=2)

    ref, _ = Executor(mk()).scaffold().run()
    ex = Executor(mk(), ckpt_dir=str(tmp_path)).scaffold()
    ex.run(rounds=2)
    ex2 = Executor(mk(), ckpt_dir=str(tmp_path)).scaffold()
    assert ex2.round_idx == 2, "resume must land on the saved boundary"
    s2, _ = ex2.run()
    _assert_bitwise_equal(_params(ref), _params(s2))
