"""Streaming client plane tests: ragged cohorts + slab staging.

The contracts (ISSUE 10):

- streaming == resident **bitwise**: both stagers feed identical slab bytes
  into ONE compiled program, so swapping the staging backend can never move
  a trajectory (sync and async).
- chunked == unchunked under the ragged plane (the driver contract extends).
- checkpoint save/resume mid-stream is bitwise the uninterrupted run.
- ``n_clients``/``cohort`` become sweepable axes: a ragged campaign lane is
  bitwise its independent single run AND the whole grid compiles ONE
  program (``Executor.compiled_programs``).
- a population far larger than device memory trains at a working set
  bounded by the cohort slab — asserted off the ``staged_bytes`` telemetry
  counters.
- bad cohort geometry fails loudly at load time (``jobs.validate_cohort``).
"""
import os

os.environ.setdefault("REPRO_KERNEL_IMPL", "jnp")

import jax
import numpy as np
import pytest

from repro.core.jobs import load_job
from repro.runtime.campaign import CampaignExecutor
from repro.runtime.executor import Executor
from repro.telemetry.recorder import read_events


def _job(sweep=None, telemetry=None, strategy="fedavg", **tp):
    params = {"n_clients": 8, "cohort": 4, "max_cohort": 6,
              "local_epochs": 1, "client_lr": 0.1, "rounds": 4, "seed": 11,
              "rounds_per_launch": 2, "batch_size": 4, "local_steps": 2}
    params.update(tp)
    cfg = {
        "name": "stream",
        "model": {"arch": "flsim-mlp"},
        "dataset": {"dataset": "synthetic_vision", "n_items": 128,
                    "distribution": {"partition": "dirichlet",
                                     "dirichlet_alpha": 0.5}},
        "strategy": {"strategy": strategy, "train_params": params},
        "runtime": {"straggler_prob": 0.2,
                    "straggler_overprovision": 1.25},
    }
    if sweep:
        cfg["sweep"] = sweep
    if telemetry:
        cfg["telemetry"] = telemetry
    return load_job(cfg)


def _run(job, **kw):
    ex = Executor(job, **kw).scaffold()
    state, logger = ex.run()
    return (jax.tree.map(np.asarray, state["params"]),
            logger.series("loss"), ex)


def _assert_bitwise_equal(p1, p2):
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_streaming_equals_resident_bitwise():
    """The tentpole contract: per-chunk host staging of only the sampled
    cohorts' shards feeds the SAME compiled program the resident gather
    feeds — identical slab bytes, identical trajectory, bitwise."""
    p_res, l_res, ex = _run(_job())
    p_str, l_str, _ = _run(_job(streaming=True))
    assert l_res == l_str, "streaming moved the loss trajectory"
    _assert_bitwise_equal(p_res, p_str)
    assert ex.stager is not None and ex.stager.peak_slab_bytes > 0


def test_ragged_chunked_equals_unchunked():
    """The driver's fusion contract extends to the ragged plane: the slab
    is addressed by absolute round index, so chunk boundaries are
    unobservable (streaming included)."""
    p1, l1, _ = _run(_job(streaming=True, rounds_per_launch=1))
    p4, l4, _ = _run(_job(streaming=True, rounds_per_launch=4))
    assert l1 == l4
    _assert_bitwise_equal(p1, p4)


def test_async_streaming_equals_resident():
    """Async ragged: the per-event slab row is gathered by the real client
    id off the schedule, so the event stream is bitwise invariant to the
    staging backend — and to the ragged plane itself (same draw as
    ``gather_one_client_batch``)."""
    kw = dict(mode="async", async_buffer=3, max_staleness=2,
              rounds_per_launch=1, rounds=3, n_clients=6, cohort=0,
              max_cohort=6)
    p_dense, l_dense, _ = _run(_job(**dict(kw, max_cohort=0)))
    p_res, l_res, _ = _run(_job(**kw))
    p_str, l_str, _ = _run(_job(**dict(kw, streaming=True)))
    assert l_res == l_str
    _assert_bitwise_equal(p_res, p_str)
    assert l_dense == l_res, "ragged changed the async event stream"
    _assert_bitwise_equal(p_dense, p_res)


def test_checkpoint_resume_mid_stream(tmp_path):
    """Interrupting a streaming run at a chunk boundary and resuming from
    the checkpoint is bitwise the uninterrupted run (the stager addresses
    absolute rounds, so a resumed chunk re-stages exactly what the
    uninterrupted run staged)."""
    mk = lambda: _job(streaming=True, rounds=6, checkpoint_every=2)
    p_full, _, _ = _run(mk())
    ex1 = Executor(mk(), ckpt_dir=str(tmp_path)).scaffold()
    ex1.run(rounds=4)
    p_res, _, _ = _run(mk(), ckpt_dir=str(tmp_path))
    _assert_bitwise_equal(p_full, p_res)


def test_cohort_sweep_one_program_bitwise():
    """The sweepable-axes contract: a ragged campaign over n_clients x
    cohort compiles ONE program (the sizes are host-side slab-plan values,
    not trace shapes), and every lane is bitwise its independent single
    run."""
    camp = CampaignExecutor(
        _job(sweep={"n_clients": [6, 8], "cohort": [2, 4]}))
    camp.scaffold()
    camp.run()
    assert camp.compiled_programs() == 1
    for s, coord in enumerate(camp.coords):
        p_single, _, _ = _run(_job(**coord))
        _assert_bitwise_equal(camp.trajectory_params(s), p_single)


def test_population_bounded_working_set(tmp_path):
    """A synthetic population too large to stage resident trains through
    the sync driver with a per-chunk working set bounded by the cohort
    slab — the ``staged_bytes`` counters report slab vs resident-equivalent
    bytes, and the ratio must be tiny."""
    job = load_job({
        "name": "pop", "model": {"arch": "flsim-logreg"},
        "dataset": {"dataset": "synthetic_population", "n_items": 20_000,
                    "items_per_client": 8},
        "strategy": {"strategy": "fedavg",
                     "train_params": {"n_clients": 20_000, "cohort": 8,
                                      "max_cohort": 10, "streaming": True,
                                      "client_lr": 0.1, "rounds": 2,
                                      "seed": 1, "rounds_per_launch": 2,
                                      "batch_size": 4, "local_steps": 1}},
        "telemetry": {"enabled": True, "out_dir": str(tmp_path)},
    })
    state, logger = Executor(job).scaffold().run()
    assert np.isfinite(logger.series("loss")).all()
    evs = [e["values"] for e in read_events(str(tmp_path))
           if e.get("kind") == "counter" and e.get("name") == "staged_bytes"
           and "slab" in e.get("values", {})]
    assert evs, "no per-chunk staged_bytes counters recorded"
    for v in evs:
        assert v["peak_slab"] <= v["slab"] * 2
        assert v["peak_slab"] < 0.01 * v["resident_equiv"], v


def test_cohort_validation_errors():
    """Bad cohort geometry fails at load, not mid-campaign: an oversized
    cohort must not silently clamp, an undersized slab must not silently
    truncate, and streaming requires the ragged plane."""
    with pytest.raises(ValueError, match="cohort"):
        load_job({"name": "bad", "model": {"arch": "flsim-logreg"},
                  "dataset": {"dataset": "synthetic_vision", "n_items": 32},
                  "strategy": {"strategy": "fedavg",
                               "train_params": {"n_clients": 4,
                                                "cohort": 8}}})
    with pytest.raises(ValueError, match="max_cohort"):
        load_job({"name": "bad", "model": {"arch": "flsim-logreg"},
                  "dataset": {"dataset": "synthetic_vision", "n_items": 32},
                  "strategy": {"strategy": "fedavg",
                               "train_params": {"n_clients": 8, "cohort": 4,
                                                "max_cohort": 2}}})
    with pytest.raises(ValueError, match="streaming"):
        load_job({"name": "bad", "model": {"arch": "flsim-logreg"},
                  "dataset": {"dataset": "synthetic_vision", "n_items": 32},
                  "strategy": {"strategy": "fedavg",
                               "train_params": {"n_clients": 8, "cohort": 4,
                                                "streaming": True}}})


def test_population_requires_streaming():
    """A shard-factory population cannot be staged resident."""
    with pytest.raises(ValueError, match="streaming"):
        job = load_job({
            "name": "pop", "model": {"arch": "flsim-logreg"},
            "dataset": {"dataset": "synthetic_population", "n_items": 100,
                        "items_per_client": 4},
            "strategy": {"strategy": "fedavg",
                         "train_params": {"n_clients": 100, "cohort": 4,
                                          "max_cohort": 6,
                                          "client_lr": 0.1, "rounds": 1}}})
        Executor(job).scaffold()


def test_ragged_rejects_client_state_strategies():
    """SCAFFOLD-style per-client carried state indexes a dense (C, ...)
    plane; the ragged plane must refuse it loudly instead of training with
    silently wrong control variates."""
    with pytest.raises((ValueError, NotImplementedError),
                       match="(?i)client.state|scaffold|ragged"):
        Executor(_job(strategy="scaffold")).scaffold()
