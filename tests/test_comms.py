"""Comms observatory tests (core/netmodel.py + telemetry/comms.py + drains).

The load-bearing contract is that the comms plane is *pure host-side
accounting*: running any driver (sync, async, campaign, planner bucket)
with ``comms: {enabled: true}`` must produce bit-identical params and
metrics to the same run with comms off, and byte totals must be invariant
across chunk sizes (the accountants advance strictly in round order). On
top of that: the traffic-matrix invariants (gossip symmetry +
``gossip_steps`` scaling, hierarchical intra/cross split, int8 ≈ dense/4 +
scale overhead, masked/rejected clients bill zero uplink), the LinkModel's
prefix-stable Philox tag (schedules bitwise identical with link knobs on
or off), the simulated wall-clock identity between the sync driver and an
equal-speeds FedBuff(buffer == cohort) run, and the artifact plumbing
(comms.csv, per-lane Perfetto counters, ``comms_total`` in the trace
report, ``sim_time_s``/``cum_bytes`` joined onto result rows). Satellites:
``get_topology`` did-you-mean, ``build_schedule`` degenerate-input
validation, ``vtime`` threading into async logger/ledger rows.
"""
import os
import types

os.environ.setdefault("REPRO_KERNEL_IMPL", "jnp")

import jax
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core import netmodel
from repro.core.jobs import load_job
from repro.core.netmodel import (LaneComms, client_links, consensus_nbytes,
                                 dense_nbytes, gossip_matrix,
                                 hierarchical_nbytes, round_nbytes,
                                 shape_template, topk_nbytes, uplink_nbytes)
from repro.core.packing import QBLOCK
from repro.core.probes import read_probes
from repro.core.topology import get_topology
from repro.runtime.campaign import CampaignExecutor
from repro.runtime.clock import ClientSystemModel, build_schedule
from repro.runtime.executor import Executor
from repro.telemetry.comms import CommsSpec
from repro.telemetry.trace import report

_COMMS_ON = {"enabled": True}
_EQUAL_SPEEDS = {"duration_sigma": 0.0, "rate_spread": 0.0,
                 "straggler_prob": 0.0}


def _raw(*, mode="sync", rounds=4, chunk=2, sweep=None, comms=None,
         telemetry=None, runtime=None, consensus=None, seed=3,
         strategy="fedavg", **tp_extra):
    tp = {"n_clients": 4, "local_epochs": 1, "client_lr": 0.1,
          "rounds": rounds, "seed": seed, "rounds_per_launch": chunk}
    if mode == "async":
        tp.update({"mode": "async", "async_buffer": 3, "max_staleness": 4,
                   "staleness_exponent": 0.5})
    tp.update(tp_extra)
    raw = {
        "name": "comms-test",
        "model": {"arch": "flsim-logreg"},
        "dataset": {"dataset": "synthetic_vision", "n_items": 128,
                    "distribution": {"partition": "dirichlet",
                                     "dirichlet_alpha": 0.5}},
        "strategy": {"strategy": strategy, "train_params": tp},
    }
    for key, val in (("sweep", sweep), ("comms", comms),
                     ("telemetry", telemetry), ("runtime", runtime),
                     ("consensus", consensus)):
        if val is not None:
            raw[key] = val
    return raw


def _params(state):
    return jax.tree.map(np.asarray, state["params"])


def _assert_bitwise_equal(p1, p2):
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _run(raw):
    ex = Executor(load_job(raw)).scaffold()
    state, logger = ex.run()
    return ex, state, logger


# block-aligned shapes so the int8 padding overhead is purely the scales
_TPL = [netmodel._ShapeLeaf((256, 8)), netmodel._ShapeLeaf((256,))]


# ---------------------------------------------------------------------------
# payload sizes: int8 / topk / dense wire bytes
# ---------------------------------------------------------------------------

def test_int8_bytes_quarter_dense_plus_scales():
    dense = dense_nbytes(_TPL)
    int8 = uplink_nbytes(_TPL, FLConfig(compression="int8"))
    n = sum(leaf.size for leaf in _TPL)
    # 1 byte/value + 4 bytes per qblock scale: ~0.25x + per-block overhead
    assert int8 == n + 4 * (n // QBLOCK)
    assert 0.25 * dense < int8 <= 0.30 * dense


def test_topk_bytes_are_index_value_pairs():
    fl = FLConfig(compression="topk", topk_ratio=0.1)
    n = sum(leaf.size for leaf in _TPL)
    assert uplink_nbytes(_TPL, fl) == 8 * int(np.ceil(0.1 * n))
    assert topk_nbytes(_TPL, 1e-9) == 8     # at least one coordinate


def test_downlink_is_always_dense():
    up, down = netmodel.payload_nbytes(_TPL, FLConfig(compression="int8"))
    assert down == dense_nbytes(_TPL) and up < down


# ---------------------------------------------------------------------------
# traffic-matrix invariants (satellite)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("steps", [1, 3])
def test_gossip_matrix_symmetric_and_scales_with_steps(steps):
    m = gossip_matrix(6, 1000, steps)
    np.testing.assert_array_equal(m, m.T)
    assert np.diagonal(m).sum() == 0
    # each client sends its state to both ring neighbours, per step
    assert m.sum() == 6 * 2 * 1000 * steps
    np.testing.assert_array_equal(m, steps * gossip_matrix(6, 1000, 1))


def test_gossip_matrix_degenerate_sizes():
    assert gossip_matrix(1, 1000).sum() == 0
    # C=2: both ring neighbours of client 0 are client 1 -> doubled cell
    m = gossip_matrix(2, 10)
    assert m[0, 1] == m[1, 0] == 20


def test_hierarchical_two_tier_split():
    intra, cross = hierarchical_nbytes(400, 1600, 1000, pods=4)
    assert intra == 2000                    # client <-> edge exchange
    assert cross == 2 * 4 * 1000            # pod aggregate up + global down
    sb = dense_nbytes(_TPL)
    total = round_nbytes(_TPL, FLConfig(topology="hierarchical",
                                        n_clients=4), pods=4)
    assert total == 4 * 2 * sb + 2 * 4 * sb


def test_consensus_overlay_bytes():
    sb = dense_nbytes(_TPL)
    assert consensus_nbytes(FLConfig(n_workers=1), sb) == 0
    three = consensus_nbytes(FLConfig(n_workers=3), sb)
    assert three == 3 * 2 * sb + 3 * 2 * 16     # shares + digest votes


def test_masked_clients_bill_zero_uplink():
    fl = FLConfig(n_clients=8, cohort=3)
    lane = LaneComms(fl=fl, csm=ClientSystemModel(seed=0), template=_TPL)
    cols = lane.sync_rounds(0, 4)
    up, down = netmodel.payload_nbytes(_TPL, fl)
    assert (cols["up_bytes"] == 3 * up).all()
    assert (cols["down_bytes"] == 3 * down).all()


def test_rejected_async_arrivals_bill_zero_uplink():
    fl = FLConfig(n_clients=4)

    def sched(accept):
        return types.SimpleNamespace(
            client=np.array([0, 1, 2, 3, 0, 1, 2, 3]),
            task=np.zeros(8, np.int32),
            accept=np.asarray(accept, bool),
            vtime=np.linspace(1.0, 8.0, 8))

    lane = LaneComms(fl=fl, csm=ClientSystemModel(seed=0), template=_TPL)
    cols = lane.async_rounds(0, 2, sched([True, False, True, False] * 2),
                             events_per_round=4)
    assert (cols["up_bytes"] == 2 * lane.up_payload).all()
    assert (cols["down_bytes"] == 4 * lane.down_payload).all()
    lane2 = LaneComms(fl=fl, csm=ClientSystemModel(seed=0), template=_TPL)
    cols2 = lane2.async_rounds(0, 2, sched([False] * 8),
                               events_per_round=4)
    assert (cols2["up_bytes"] == 0).all()
    assert (cols2["down_bytes"] > 0).all()


def test_decentralized_rounds_symmetric_and_scale_with_steps():
    def total_up(steps):
        fl = FLConfig(n_clients=4, topology="decentralized",
                      gossip_steps=steps)
        lane = LaneComms(fl=fl, csm=ClientSystemModel(seed=0),
                         template=_TPL)
        cols = lane.sync_rounds(0, 2)
        assert (cols["up_bytes"] == cols["down_bytes"]).all()
        return cols["up_bytes"].sum()
    assert total_up(3) == 3 * total_up(1)


def test_blockchain_block_billed_per_round():
    fl = FLConfig(n_clients=4, blockchain="hashchain")
    lane = LaneComms(fl=fl, csm=ClientSystemModel(seed=0), template=_TPL)
    cols = lane.sync_rounds(0, 3)
    assert (cols["overlay_bytes"] == netmodel.BLOCK_NBYTES).all()


# ---------------------------------------------------------------------------
# LinkModel: seed-pure draws on a dedicated tag, schedules prefix-stable
# ---------------------------------------------------------------------------

def test_client_links_deterministic_and_tiered():
    csm = ClientSystemModel(seed=7, link_tiers=4)
    a, b = client_links(csm, 16), client_links(csm, 16)
    np.testing.assert_array_equal(a.up_Bps, b.up_Bps)
    assert len(np.unique(a.up_Bps)) > 1       # tiers actually differ
    # prefix-stable: the first 8 clients keep their links at C=16
    np.testing.assert_array_equal(client_links(csm, 8).up_Bps,
                                  a.up_Bps[:8])
    homo = client_links(ClientSystemModel(seed=7), 16)
    assert len(np.unique(homo.up_Bps)) == 1


def test_schedule_bitwise_invariant_to_link_knobs():
    w = np.ones(4, np.float32)
    plain = build_schedule(ClientSystemModel(seed=3), 4, 16, w)
    linked = build_schedule(
        ClientSystemModel(seed=3, link_tiers=4, up_mbps=10.0,
                          latency_s=0.2), 4, 16, w)
    for f in ("client", "task", "accept", "vtime", "staleness"):
        np.testing.assert_array_equal(np.asarray(getattr(plain, f)),
                                      np.asarray(getattr(linked, f)))


# ---------------------------------------------------------------------------
# bitwise invariance + chunking invariance through the drivers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["sync", "async"])
def test_bitwise_comms_on_vs_off(mode):
    ex_on, s_on, log_on = _run(_raw(mode=mode, comms=_COMMS_ON))
    _, s_off, log_off = _run(_raw(mode=mode))
    _assert_bitwise_equal(_params(s_off), _params(s_on))
    assert log_on.series("loss") == log_off.series("loss")
    assert len(ex_on.comms_rows) == 4


def test_campaign_bitwise_comms_on_vs_off():
    sweep = {"seeds": [3, 5]}
    c_off = CampaignExecutor(load_job(_raw(sweep=sweep))).scaffold()
    c_off.run()
    c_on = CampaignExecutor(load_job(
        _raw(sweep=sweep, comms=_COMMS_ON))).scaffold()
    c_on.run()
    for s in range(2):
        _assert_bitwise_equal(c_off.trajectory_params(s),
                              c_on.trajectory_params(s))
    # one row per (lane, round), keyed by sweep coords like campaign.csv
    assert len(c_on.comms_rows) == 2 * 4
    assert {r["seed"] for r in c_on.comms_rows} == {3, 5}
    # the result rows carry the curve x-axes
    assert all("sim_time_s" in r and "cum_bytes" in r for r in c_on.results)


@pytest.mark.parametrize("mode", ["sync", "async"])
def test_comms_rows_chunking_invariant(mode):
    ex1, _, _ = _run(_raw(mode=mode, chunk=1, comms=_COMMS_ON))
    ex4, _, _ = _run(_raw(mode=mode, chunk=4, comms=_COMMS_ON))
    assert ex1.comms_rows == ex4.comms_rows


def test_ledger_digests_invariant_to_comms():
    kw = dict(consensus={"blockchain": "hashchain"})
    ex_on, _, _ = _run(_raw(comms=_COMMS_ON, **kw))
    ex_off, _, _ = _run(_raw(**kw))
    chain = [b.payload for b in ex_on.job.ledger.blocks()
             if b.kind == "global"]
    chain_off = [b.payload for b in ex_off.job.ledger.blocks()
                 if b.kind == "global"]
    assert chain and chain == chain_off


# ---------------------------------------------------------------------------
# simulated wall-clock: deterministic, sync == equal-speeds FedBuff
# ---------------------------------------------------------------------------

def test_sim_clock_seed_pure():
    ex1, _, _ = _run(_raw(comms=_COMMS_ON))
    ex2, _, _ = _run(_raw(comms=_COMMS_ON))
    assert ex1.comms_rows == ex2.comms_rows
    assert (np.diff([r["sim_time_s"] for r in ex1.comms_rows]) > 0).all()


def test_sync_matches_equal_speeds_fedbuff():
    """On the FedAvg-identity configuration (equal speeds, FedBuff buffer
    == cohort) the sync makespan composition and the vtime-shifted async
    composition must agree — the same collapse the schedule itself
    guarantees for params."""
    ex_s, _, _ = _run(_raw(comms=_COMMS_ON, runtime=_EQUAL_SPEEDS))
    ex_a, _, _ = _run(_raw(mode="async", comms=_COMMS_ON,
                           runtime=_EQUAL_SPEEDS, async_buffer=4,
                           max_staleness=4, staleness_exponent=0.0))
    t_sync = [r["sim_time_s"] for r in ex_s.comms_rows]
    t_async = [r["sim_time_s"] for r in ex_a.comms_rows]
    np.testing.assert_allclose(t_sync, t_async, rtol=1e-9)


# ---------------------------------------------------------------------------
# artifact plumbing: comms.csv, counter tracks, trace report
# ---------------------------------------------------------------------------

def test_comms_csv_and_counter_tracks(tmp_path):
    ex, _, _ = _run(_raw(comms=_COMMS_ON,
                         telemetry={"out_dir": str(tmp_path)}))
    ex.recorder.close()
    rows = read_probes(tmp_path / "comms.csv")
    assert len(rows) == 4
    assert rows == ex.comms_rows        # csv round-trips the full buffer
    counters = {e["name"] for e in ex.recorder.events
                if e.get("kind") == "counter"}
    assert {"comms:cum_up_bytes", "comms:cum_down_bytes",
            "comms:sim_time_s", "comms_total"} <= counters
    spans = {e["name"] for e in ex.recorder.events if e["kind"] == "span"}
    assert "comms_flush" in spans
    # counter samples are back-dated inside their launch span
    launch = next(e for e in ex.recorder.events
                  if e.get("name") == "launch")
    sample = next(e for e in ex.recorder.events
                  if e.get("name") == "comms:cum_up_bytes")
    assert launch["t0_us"] <= sample["t_us"] \
        <= launch["t0_us"] + launch["dur_us"]
    # the trace report renders the comms section off comms_total
    text = report([dict(e) for e in ex.recorder.events])
    assert "up_MB" in text and "sim_s" in text


def test_campaign_per_lane_comms_counters_and_csv(tmp_path):
    c = CampaignExecutor(load_job(_raw(
        sweep={"seeds": [3, 5]},
        telemetry={"out_dir": str(tmp_path)},
        comms={"enabled": True, "out_dir": str(tmp_path)}))).scaffold()
    c.run()
    sample = next(e for e in c.recorder.events
                  if e.get("name") == "comms:cum_up_bytes")
    assert set(sample["values"]) == {"lane0", "lane1"}
    totals = [e for e in c.recorder.events
              if e.get("name") == "comms_total"]
    assert {v["values"]["lane"] for v in totals} == {0, 1}
    rows = read_probes(tmp_path / "comms.csv")
    assert len(rows) == 8
    assert {(r["seed"], r["traj"]) for r in rows} == {(3, 0), (5, 1)}


def test_comms_memory_only_without_out_dir():
    ex, _, _ = _run(_raw(comms=_COMMS_ON))
    assert ex._comms_path() is None and len(ex.comms_rows) == 4


def test_plan_int8_lane_uplink_ratio(tmp_path):
    """The acceptance campaign: a ``compression: [none, int8]`` sweep
    reports int8 lane uplink <= 0.30x dense in the merged comms.csv."""
    from repro.runtime.scheduler import PlanExecutor
    px = PlanExecutor(load_job(_raw(
        sweep={"compression": ["none", "int8"]}, comms=_COMMS_ON)),
        out_dir=str(tmp_path)).scaffold()
    px.run()
    rows = read_probes(tmp_path / "comms.csv")
    last = {r["compression"]: r for r in rows if r["round"] == 3}
    ratio = last["int8"]["cum_up_bytes"] / last["none"]["cum_up_bytes"]
    assert ratio <= 0.30
    assert last["int8"]["cum_down_bytes"] == last["none"]["cum_down_bytes"]
    # both lanes' params bitwise-match their comms-off plan
    px_off = PlanExecutor(load_job(_raw(
        sweep={"compression": ["none", "int8"]}))).scaffold()
    px_off.run()
    for lane in range(2):
        _assert_bitwise_equal(px.lane_params(lane), px_off.lane_params(lane))


# ---------------------------------------------------------------------------
# figures: time-/bytes-to-accuracy reuse the banded grouping
# ---------------------------------------------------------------------------

def test_time_and_bytes_to_accuracy_curves():
    from benchmarks.figures import bytes_to_accuracy, time_to_accuracy
    c = CampaignExecutor(load_job(_raw(
        sweep={"seeds": [3, 5]}, comms=_COMMS_ON))).scaffold()
    c.run()
    curves = time_to_accuracy(c.results, metric="loss")
    assert len(curves) == 1               # seeds pool into one band
    assert curves[0]["x"] == sorted(curves[0]["x"])
    bcurves = bytes_to_accuracy(c.results, metric="loss")
    assert bcurves[0]["x"][-1] > bcurves[0]["x"][0] > 0


# ---------------------------------------------------------------------------
# satellites: topology did-you-mean, schedule validation, vtime threading
# ---------------------------------------------------------------------------

def test_get_topology_did_you_mean():
    with pytest.raises(ValueError, match="client_server"):
        get_topology("client-server")
    with pytest.raises(ValueError, match="known"):
        get_topology("zzz")


def test_build_schedule_rejects_degenerate_inputs():
    with pytest.raises(ValueError, match="n_events"):
        build_schedule(ClientSystemModel(seed=0), 4, 0,
                       np.ones(4, np.float32))
    with pytest.raises(ValueError, match="n_clients"):
        build_schedule(ClientSystemModel(seed=0), 0, 8,
                       np.ones(0, np.float32))


def test_async_rows_carry_vtime_without_comms():
    _, _, logger = _run(_raw(mode="async"))
    vt = [r["vtime"] for r in logger.rows]
    assert len(vt) == 4 and vt == sorted(vt) and vt[0] > 0


def test_async_digest_blocks_carry_vtime():
    ex, _, _ = _run(_raw(mode="async", digest_every_events=4,
                         consensus={"blockchain": "hashchain"}))
    digests = [b for b in ex.job.ledger.blocks()
               if b.kind == "async_digest"]
    assert digests
    assert all(b.payload["vtime"] > 0 for b in digests)


def test_comms_spec_validation():
    with pytest.raises(ValueError, match="pods"):
        CommsSpec(enabled=True, pods=0)
    with pytest.raises(KeyError, match="enabled"):
        load_job(_raw(comms={"enabld": True}))
    assert not CommsSpec.from_job(load_job(_raw())).enabled
    assert CommsSpec.from_job(
        load_job(_raw(comms={"enabled": True, "pods": 2}))).pods == 2


def test_campaign_template_strips_lane_dim():
    c = CampaignExecutor(load_job(_raw(
        sweep={"seeds": [3, 5]}, comms=_COMMS_ON))).scaffold()
    single = Executor(load_job(_raw(comms=_COMMS_ON))).scaffold()
    assert c._comms[0].state_nbytes == single._comms[0].state_nbytes


def test_shape_template_strips_leading():
    t = {"w": np.zeros((3, 4, 5))}
    assert dense_nbytes(shape_template(t)) == 4 * 60
    assert dense_nbytes(shape_template(t, strip_leading=True)) == 4 * 20
