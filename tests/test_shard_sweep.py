"""Device-parallel campaign tests: the sweep axis sharded over a lane mesh.

The sharding determinism contract extends the campaign contracts
(tests/test_sweeps.py, tests/test_plan.py) along the *device* axis: lane
``s`` of a campaign sharded over an n-device lane mesh is bitwise identical
to the same campaign's 1-device vmap lane AND to an independent single run
— for sync and async buckets, with and without a lane scheduler, and across
chunkings. S that doesn't divide the device count pads with dead lanes
(``alive = 0`` maskwork through ``rounds.freeze_unless``, the same select a
scheduler drop uses), and padded lanes never reach the results table or the
ledger.

Needs a multi-device host: CI's ``multidevice`` job (and local runs) set
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` with
``JAX_PLATFORMS=cpu`` before jax initializes; under the plain 1-device
tier this module skips.
"""
import os

os.environ.setdefault("REPRO_KERNEL_IMPL", "jnp")

import jax
import numpy as np
import pytest

from repro.core.jobs import load_job
from repro.runtime.campaign import CampaignExecutor
from repro.runtime.executor import Executor
from repro.runtime.scheduler import PlanExecutor, SuccessiveHalving

DEVICES = 4

pytestmark = pytest.mark.skipif(
    jax.device_count() < DEVICES,
    reason=f"lane-mesh tests need {DEVICES} devices; run with "
           f"XLA_FLAGS=--xla_force_host_platform_device_count={DEVICES} "
           "(see CI's multidevice job)")


def _raw(coord=None, sweep=None, *, mode="sync", rounds=3, chunk=3,
         n_clients=4, n_items=96, strategy="fedavg"):
    """One job dict; ``coord`` overrides land in their proper sections (the
    single-run references for each campaign lane are built this way)."""
    coord = coord or {}
    tp = {"n_clients": n_clients, "local_epochs": 1,
          "client_lr": coord.get("client_lr", 0.1),
          "rounds": rounds, "seed": coord.get("seed", 3),
          "rounds_per_launch": chunk}
    runtime = {"straggler_prob": 0.2, "straggler_overprovision": 1.25}
    if mode == "async":
        tp.update({"mode": "async", "async_buffer": 3, "max_staleness": 4,
                   "staleness_exponent": coord.get("staleness_exponent",
                                                   0.5)})
        runtime = {"straggler_prob": 0.2, "duration_sigma": 0.25}
    raw = {
        "name": "shard-test",
        "model": {"arch": "flsim-logreg"},
        "dataset": {"dataset": "synthetic_vision", "n_items": n_items,
                    "distribution": {
                        "partition": "dirichlet",
                        "dirichlet_alpha": coord.get("dirichlet_alpha",
                                                     0.5)}},
        "strategy": {"strategy": coord.get("strategy", strategy),
                     "train_params": tp},
        "runtime": runtime,
    }
    if sweep:
        raw["sweep"] = sweep
    return raw


def _assert_bitwise_equal(p1, p2):
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _lanes_match(sharded, vmapped, mk_raw):
    """Every lane: sharded == 1-device vmap == independent single run."""
    for s, coord in enumerate(sharded.spec.coords()):
        _assert_bitwise_equal(vmapped.trajectory_params(s),
                              sharded.trajectory_params(s))
        state, _ = Executor(load_job(mk_raw(coord))).scaffold().run()
        _assert_bitwise_equal(jax.tree.map(np.asarray, state["params"]),
                              sharded.trajectory_params(s))


# ---------------------------------------------------------------------------
# the sharding determinism contract
# ---------------------------------------------------------------------------

def test_sharded_sync_campaign_bitwise():
    """S=16 seeds x alpha x lr grid over 4 devices: every lane bitwise the
    1-device vmap lane and its independent single run; the per-lane planes
    actually shard while the concatenated data roots replicate."""
    sweep = {"seeds": [3, 5, 7, 9], "dirichlet_alpha": [0.3, 3.0],
             "client_lr": [0.05, 0.1]}
    vm = CampaignExecutor(load_job(_raw(sweep=sweep))).scaffold()
    vm.run()
    sh = CampaignExecutor(load_job(_raw(sweep=sweep)),
                          lane_devices=DEVICES).scaffold()
    sh.run()
    assert sh.S == 16 and sh.S_pad == 16 and not sh._thread_alive
    # placement: idx/len/scalars/state shard over lanes, roots replicate
    assert len(sh.staged["idx"].sharding.device_set) == DEVICES
    assert not sh.staged["idx"].sharding.is_fully_replicated
    assert sh.staged["x"].sharding.is_fully_replicated
    assert not jax.tree.leaves(
        sh.state["params"])[0].sharding.is_fully_replicated
    _lanes_match(sh, vm, _raw)


def test_sharded_padding_is_dead_lane_maskwork():
    """S=6 pads to 8 over 4 devices: real lanes stay bitwise their vmap /
    single-run counterparts, pad lanes are alive=0 from launch 1 and never
    reach the results table."""
    sweep = {"seeds": [3, 5, 7], "client_lr": [0.05, 0.1]}
    vm = CampaignExecutor(load_job(_raw(sweep=sweep))).scaffold()
    vm.run()
    sh = CampaignExecutor(load_job(_raw(sweep=sweep)),
                          lane_devices=DEVICES).scaffold()
    sh.run()
    assert sh.S == 6 and sh.S_pad == 8
    assert sh._thread_alive          # padding threads the alive mask ...
    assert not sh.lane_scheduling    # ... even with no scheduler attached
    np.testing.assert_array_equal(sh.alive, [1, 1, 1, 1, 1, 1, 0, 0])
    _lanes_match(sh, vm, _raw)
    assert {r["traj"] for r in sh.results} == set(range(6))
    assert len(sh.results) == 6 * 3


@pytest.mark.parametrize("mode", ["sync", "async"])
def test_sharded_chunking_invariance(mode):
    """rounds_per_launch chunking stays bitwise-invariant under the lane
    mesh (uneven 2+1 chunking included) — chunk boundaries re-enter the
    compiled program from host-visible sharded state."""
    sweep = {"seeds": [3, 5], "client_lr": [0.05, 0.1]}
    runs = {}
    for chunk in (1, 3, 2):
        camp = CampaignExecutor(
            load_job(_raw(sweep=sweep, mode=mode, chunk=chunk)),
            lane_devices=DEVICES).scaffold()
        camp.run()
        runs[chunk] = jax.tree.map(np.asarray, camp.state["params"])
    _assert_bitwise_equal(runs[1], runs[3])
    _assert_bitwise_equal(runs[1], runs[2])


def test_sharded_async_campaign_bitwise():
    """Async (FedBuff) lanes under the mesh: per-lane schedules dedup to
    (U, E) replicated + a sharded lane->schedule index, and every lane is
    bitwise its 1-device vmap lane and its single run."""
    sweep = {"seeds": [7, 9], "staleness_exponent": [0.0, 1.0],
             "client_lr": [0.05, 0.1]}
    vm = CampaignExecutor(
        load_job(_raw({"seed": 7}, sweep=sweep, mode="async",
                      chunk=2))).scaffold()
    vm.run()
    sh = CampaignExecutor(
        load_job(_raw({"seed": 7}, sweep=sweep, mode="async", chunk=2)),
        lane_devices=DEVICES).scaffold()
    sh.run()
    assert sh.S == 8
    # schedule plane: 2 seeds x 2 exponents = 4 unique schedules, 8 lanes
    assert sh.sched_dev["client"].shape[0] == 4
    np.testing.assert_array_equal(sh.lane_sched, [0, 0, 1, 1, 2, 2, 3, 3])
    assert sh.sched_dev["client"].sharding.is_fully_replicated
    _lanes_match(sh, vm, lambda c: _raw(c, mode="async", chunk=2))


# ---------------------------------------------------------------------------
# planner + scheduler under the mesh
# ---------------------------------------------------------------------------

def test_sharded_plan_scheduler_device_count_independent():
    """A scheduled heterogeneous campaign drops the same lanes — and every
    lane's params stay bitwise — whether the buckets run on 1 device or
    sharded over 4: halving decisions are host-side functions of the tidy
    table, whose rows regenerate identically under the mesh. Bucket sizes
    (3 lanes each) don't divide the device count, so each bucket also pads
    independently."""
    sweep = {"strategy": ["fedavg", "fedprox"], "seeds": [3, 5, 7]}

    def mk(lane_devices):
        return PlanExecutor(load_job(_raw(sweep=sweep, rounds=3, chunk=1)),
                            scheduler=SuccessiveHalving(rung_every=1,
                                                        min_lanes=2),
                            lane_devices=lane_devices).scaffold()

    pe1 = mk(0)
    pe1.run()
    pe4 = mk(DEVICES)
    assert all(ex.S == 3 and ex.S_pad == 4 for ex in pe4.execs)
    pe4.run()
    assert pe4.dropped == pe1.dropped and len(pe4.dropped) > 0
    for lane in range(pe4.S):
        _assert_bitwise_equal(pe1.lane_params(lane), pe4.lane_params(lane))


def test_sharded_campaign_checkpoint_resume(tmp_path):
    """Crash + resume under the mesh: the checkpoint stores full logical
    arrays, the restore re-places them lane-sharded, and the resumed
    trajectory is bitwise the uninterrupted one."""
    sweep = {"seeds": [3, 5, 7, 9]}

    def mk(out):
        raw = _raw(sweep=sweep, rounds=4, chunk=2)
        raw["strategy"]["train_params"]["checkpoint_every"] = 2
        return CampaignExecutor(load_job(raw), out_dir=str(out),
                                ckpt_dir=str(tmp_path / "ckpt"),
                                lane_devices=DEVICES)

    full = CampaignExecutor(load_job(_raw(sweep=sweep, rounds=4, chunk=2)),
                            lane_devices=DEVICES).scaffold()
    full.run()
    ex = mk(tmp_path / "a").scaffold()
    ex.run(rounds=2)                     # crash after the first chunk
    ex2 = mk(tmp_path / "a").scaffold()  # resumes at round 2
    assert ex2.round_idx == 2
    assert not jax.tree.leaves(
        ex2.state["params"])[0].sharding.is_fully_replicated
    ex2.run()
    _assert_bitwise_equal(jax.tree.map(np.asarray, full.state["params"]),
                          jax.tree.map(np.asarray, ex2.state["params"]))


def test_elastic_resume_across_device_counts(tmp_path):
    """A checkpoint written under one lane_devices resumes under another:
    the saved arrays carry the *saving* process's S_pad, the restore keeps
    the S real lanes and re-pads from the fresh scaffold (pad lanes are
    frozen at init, which the scaffold rebuilds bitwise) — so 4-device
    save -> 1-device resume and the reverse both reproduce the
    uninterrupted run exactly. S=6 makes the two pad sizes differ (8 vs
    6)."""
    sweep = {"seeds": [3, 5, 7], "client_lr": [0.05, 0.1]}

    def mk(lane_devices, ck):
        raw = _raw(sweep=sweep, rounds=4, chunk=2)
        raw["strategy"]["train_params"]["checkpoint_every"] = 2
        return CampaignExecutor(load_job(raw), ckpt_dir=str(ck),
                                lane_devices=lane_devices)

    full = CampaignExecutor(load_job(_raw(sweep=sweep, rounds=4,
                                          chunk=2))).scaffold()
    full.run()
    for save_d, resume_d in ((DEVICES, 0), (0, DEVICES)):
        ck = tmp_path / f"ck_{save_d}_{resume_d}"
        ex = mk(save_d, ck).scaffold()
        ex.run(rounds=2)                  # crash after the first chunk
        ex2 = mk(resume_d, ck).scaffold()
        assert ex2.round_idx == 2
        assert jax.tree.leaves(ex2.state["params"])[0].shape[0] == ex2.S_pad
        ex2.run()
        for s in range(6):
            _assert_bitwise_equal(full.trajectory_params(s),
                                  ex2.trajectory_params(s))


def test_mesh_config_lanes_axis():
    """configs.base.MeshConfig carries the lane axis: lane_mesh accepts it
    directly, and so does CampaignExecutor(lane_devices=...)."""
    from repro.configs.base import MeshConfig
    from repro.launch.mesh import lane_mesh

    cfg = MeshConfig(lanes=DEVICES)
    assert cfg.axes[0] == "lanes" and cfg.shape[0] == DEVICES
    assert cfg.n_chips == DEVICES * MeshConfig().n_chips
    mesh = lane_mesh(cfg)
    assert mesh.axis_names == ("lanes",) and mesh.devices.shape == (DEVICES,)
    camp = CampaignExecutor(load_job(_raw(sweep={"seeds": [3, 5]})),
                            lane_devices=cfg)
    assert camp.lane_devices == DEVICES and camp.mesh is not None
    # the default MeshConfig (lanes=1, no lane axis in shape/axes) means
    # the single-device vmap, not a 1-device mesh
    off = CampaignExecutor(load_job(_raw(sweep={"seeds": [3, 5]})),
                           lane_devices=MeshConfig())
    assert off.lane_devices == 0 and off.mesh is None


def test_lane_mesh_wants_visible_devices():
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        from repro.launch.mesh import lane_mesh
        lane_mesh(jax.device_count() + 1)
