"""Per-architecture smoke tests on reduced configs (deliverable f).

For every assigned arch: instantiate the reduced config, run one forward
(loss) and one SGD train step on CPU, assert output shapes and no NaNs; run
prefill + decode and check decode-vs-full-forward consistency where cheap.
"""
import os

os.environ.setdefault("REPRO_KERNEL_IMPL", "jnp")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCHS, get_config
from repro.configs.reduce import reduced_config
from repro.models import model_zoo
from repro.sharding.axes import AxisCtx

CTX = AxisCtx()


def make_batch(cfg, key, B=2, S=32):
    ks = jax.random.split(key, 3)
    if cfg.family == "encdec":
        S_dec = max(S // cfg.dec_len_ratio, 8)
        return {
            "frames": jax.random.normal(ks[0], (B, S, cfg.d_model), jnp.float32),
            "tokens": jax.random.randint(ks[1], (B, S_dec), 0, cfg.vocab_size),
            "labels": jax.random.randint(ks[2], (B, S_dec), 0, cfg.vocab_size),
        }
    return {
        "tokens": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[2], (B, S), 0, cfg.vocab_size),
    }


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = reduced_config(get_config(arch))
    model = model_zoo.build(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = make_batch(cfg, key)

    def loss_fn(p):
        return model.loss(CTX, p, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    # one SGD step decreases... at least stays finite
    params2 = jax.tree.map(lambda p, g: p - 0.1 * g, params, grads)
    loss2 = jax.jit(loss_fn)(params2)
    assert np.isfinite(float(loss2))
    # gradient flows to every parameter group
    gnorms = jax.tree.map(lambda g: float(jnp.abs(g).sum()), grads)
    flat = jax.tree.leaves(gnorms)
    assert all(np.isfinite(x) for x in flat)
    n_zero = sum(1 for x in flat if x == 0.0)
    assert n_zero <= len(flat) * 0.2, f"{arch}: too many zero grads ({n_zero}/{len(flat)})"


@pytest.mark.parametrize("arch", ARCHS)
def test_loss_decreases_under_sgd(arch):
    cfg = reduced_config(get_config(arch))
    model = model_zoo.build(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    batch = make_batch(cfg, key)

    @jax.jit
    def step(p):
        l, g = jax.value_and_grad(lambda q: model.loss(CTX, q, batch)[0])(p)
        return jax.tree.map(lambda a, b: a - 0.05 * b, p, g), l

    losses = []
    for _ in range(5):
        params, l = step(params)
        losses.append(float(l))
    assert losses[-1] < losses[0], f"{arch}: loss did not decrease {losses}"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """Prefill on S tokens then one decode step == forward over S+1 tokens."""
    cfg = reduced_config(get_config(arch))
    model = model_zoo.build(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    B, S = 2, 32
    batch = make_batch(cfg, key, B=B, S=S)

    caches, last_logits, _ = jax.jit(
        lambda p, b: model.prefill(CTX, p, b))(params, batch)
    assert np.isfinite(np.asarray(last_logits)).all(), f"{arch}: prefill NaN"
    from repro.models.transformer import pad_caches
    caches = pad_caches(caches, 8)

    next_tok = model.greedy_token(CTX, last_logits)
    S_ctx = batch["tokens"].shape[1]
    length = jnp.full((B,), S_ctx, jnp.int32)
    logits, new_caches = jax.jit(
        lambda p, t, c, ln: model.decode_step(CTX, p, t, c, ln, tp=False))(
        params, next_tok, caches, length)
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: decode NaN"

    # consistency vs teacher-forced forward on [tokens; next_tok]
    ext = dict(batch)
    ext["tokens"] = jnp.concatenate([batch["tokens"], next_tok[:, None]], 1)
    ext["labels"] = jnp.concatenate(
        [batch["labels"], jnp.zeros((B, 1), batch["labels"].dtype)], 1)
    caches2, last2, _ = jax.jit(
        lambda p, b: model.prefill(CTX, p, b))(params, ext)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(last2),
                               atol=2e-2, rtol=2e-2)
