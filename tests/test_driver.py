"""Device-resident multi-round driver tests.

The driver's determinism contract: fusing rounds into one compiled launch
(``rounds_per_launch``) must not change the trajectory — chunked and
unchunked execution are bitwise-identical for the same seed, for both client
placements. Plus the cohort regression: the in-program weight mask and the
host-side ``select_cohort`` are the same function.
"""
import os

os.environ.setdefault("REPRO_KERNEL_IMPL", "jnp")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.jobs import load_job
from repro.runtime.executor import Executor
from repro.runtime.faults import FaultModel, cohort_mask, select_cohort


def _job(rounds_per_launch: int, placement: str = "spatial",
         rounds: int = 5, strategy: str = "fedavg"):
    return load_job({
        "name": f"driver-{placement}-{rounds_per_launch}",
        "model": {"arch": "flsim-mlp"},
        "dataset": {"dataset": "synthetic_vision", "n_items": 256,
                    "distribution": {"partition": "dirichlet",
                                     "dirichlet_alpha": 0.5}},
        "strategy": {"strategy": strategy,
                     "train_params": {"n_clients": 4, "local_epochs": 1,
                                      "client_lr": 0.1, "rounds": rounds,
                                      "seed": 11, "placement": placement,
                                      "rounds_per_launch": rounds_per_launch}},
        "runtime": {"straggler_prob": 0.2, "straggler_overprovision": 1.25},
    })


def _run(rounds_per_launch, placement):
    ex = Executor(_job(rounds_per_launch, placement)).scaffold()
    state, logger = ex.run()
    return (jax.tree.map(np.asarray, state["params"]),
            logger.series("loss"))


def _assert_bitwise_equal(p1, p2):
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("placement", ["spatial", "temporal"])
def test_chunked_equals_unchunked(placement):
    """rounds_per_launch=10 (one fused launch) == 1 (per-round launches),
    bitwise, over 5 rounds; an uneven chunking (3+2) must also agree."""
    p1, l1 = _run(1, placement)
    p10, l10 = _run(10, placement)
    assert l1 == l10, f"{placement}: per-round losses diverged"
    _assert_bitwise_equal(p1, p10)
    p3, _ = _run(3, placement)
    _assert_bitwise_equal(p1, p3)


def test_chunked_equals_unchunked_with_server_momentum():
    """The carried server state (FedAvgM momentum) must also survive fusion."""
    for chunk in (1, 5):
        ex = Executor(_job(chunk, "spatial", strategy="fedavgm")).scaffold()
        state, _ = ex.run()
        if chunk == 1:
            ref = jax.tree.map(np.asarray, state["params"])
        else:
            _assert_bitwise_equal(ref, jax.tree.map(np.asarray,
                                                    state["params"]))


def test_cohort_mask_matches_select_cohort():
    """The jittable in-program mask and the host kept-set are one function."""
    fault = FaultModel(drop_prob=0.2, straggler_prob=0.3,
                       straggler_slowdown=8.0, seed=5)
    ids = np.arange(50)
    for r in range(6):
        mask = np.asarray(cohort_mask(fault, r, 50, 20, 1.5))
        kept = select_cohort(fault, r, ids, target=20, overprovision=1.5)
        np.testing.assert_array_equal(np.where(mask > 0)[0], kept)
        assert mask.sum() <= 20


def test_cohort_mask_traced_round_idx():
    """Mask must be identical when round_idx is a traced scalar (as inside
    the multi-round scan) vs a Python int."""
    fault = FaultModel(drop_prob=0.1, straggler_prob=0.2, seed=3)
    jitted = jax.jit(lambda r: cohort_mask(fault, r, 32, 8, 1.25))
    for r in range(4):
        np.testing.assert_array_equal(
            np.asarray(jitted(jnp.int32(r))),
            np.asarray(cohort_mask(fault, r, 32, 8, 1.25)))


def test_checkpoint_cadence_survives_chunking(tmp_path):
    """checkpoint_every not divisible by rounds_per_launch must still save
    whenever a chunk crosses a multiple (not only on exact-divisor rounds)."""
    from repro.checkpoint import ckpt as ckpt_mod
    from repro.data.pipeline import SyntheticVision

    def mk():
        job = load_job({
            "name": "ckpt-cadence",
            "model": {"arch": "flsim-logreg"},
            "dataset": {"dataset": "synthetic_vision", "n_items": 64},
            "strategy": {"strategy": "fedavg",
                         "train_params": {"n_clients": 2, "client_lr": 0.1,
                                          "rounds": 6, "seed": 0,
                                          "rounds_per_launch": 3,
                                          "checkpoint_every": 2}}})
        job.dataset = SyntheticVision(n_items=64, shape=(28, 28, 1), seed=0)
        return job

    ex = Executor(mk(), ckpt_dir=str(tmp_path)).scaffold()
    ex.run(rounds=3)
    # chunk [0,3) crossed the multiple 2 -> a checkpoint must exist
    assert ckpt_mod.latest_round(str(tmp_path)) == 3
    ex.run()
    assert ckpt_mod.latest_round(str(tmp_path)) == 6
    # and resume lands on the saved boundary
    ex2 = Executor(mk(), ckpt_dir=str(tmp_path)).scaffold()
    assert ex2.round_idx == 6


def test_cohort_mask_keeps_target_without_faults():
    mask = np.asarray(cohort_mask(FaultModel(seed=0), 0, 16, 8, 2.0))
    assert mask.sum() == 8
    assert set(np.unique(mask)) <= {0.0, 1.0}
