"""Multi-worker consensus (paper RQ3) + hash-chain ledger (RQ4) tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.blockchain import HashChainLedger, get_ledger, param_digest
from repro.core.consensus import (MultiWorkerAggregator, digest,
                                  majority_digest, median_select, poison,
                                  trimmed_mean)


def agg_delta(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (128,)), "b": jnp.ones((4,))}


@pytest.mark.parametrize("n_workers,n_byz,nullified", [
    (1, 1, False),   # 1M-0H: single malicious worker poisons the model
    (2, 1, False),   # 1M-1H: tie — consensus cannot decide reliably
    (3, 1, True),    # 1M-2H: honest majority nullifies
    (4, 1, True),    # 1M-3H
])
def test_majority_nullifies_minority_poisoners(n_workers, n_byz, nullified):
    """Paper Fig. 10 semantics: > 50% honest workers nullify poisoning."""
    d = agg_delta()
    mw = MultiWorkerAggregator(n_workers, n_byz, "majority_digest")
    out = mw.run(d, jax.random.PRNGKey(1))
    same = np.allclose(np.asarray(out["w"]), np.asarray(d["w"]), atol=1e-5)
    if nullified:
        assert same, "honest majority should have selected the clean model"
    elif n_workers == 1:
        assert not same, "a single malicious worker must poison the result"


def test_median_robust_to_minority():
    d = agg_delta()
    stacked = jax.tree.map(
        lambda t: jnp.stack([t, t, t + 100.0]), d)   # 1 of 3 poisoned
    out = median_select(stacked, {})
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(d["w"]),
                               atol=1e-5)


def test_trimmed_mean_drops_outliers():
    d = agg_delta()
    stacked = jax.tree.map(
        lambda t: jnp.stack([t - 1000.0, t, t, t + 1000.0]), d)
    out = trimmed_mean(stacked, {"trim": 1})
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(d["w"]),
                               atol=1e-4)


def test_digest_deterministic_and_sensitive():
    d = agg_delta()
    assert np.allclose(np.asarray(digest(d)), np.asarray(digest(d)))
    d2 = poison(d, scale=0.1)
    assert not np.allclose(np.asarray(digest(d)), np.asarray(digest(d2)),
                           atol=1e-4)


# ---------------------------------------------------------------------------
# ledger
# ---------------------------------------------------------------------------

def test_chain_verifies_and_detects_tampering():
    led = HashChainLedger()
    p = agg_delta()
    led.record_aggregate(0, "worker_0", p)
    led.record_consensus(0, "majority_digest", param_digest(p),
                         {"worker_0": param_digest(p)})
    led.record_global(0, p)
    assert led.verify()
    led._chain[2].payload["chosen"] = "deadbeef"
    assert not led.verify()


def test_provenance_and_reputation():
    led = HashChainLedger()
    p = agg_delta()
    good = param_digest(p)
    bad = param_digest(poison(p))
    led.record_aggregate(0, "w0", p)
    led.record_consensus(0, "majority_digest", good, {"w0": good, "w1": bad})
    led.record_global(0, p)
    prov = led.provenance(good)
    assert len(prov) >= 2                      # consensus + global blocks
    assert led.reputation["w0"] > led.reputation["w1"]


def test_ledger_registry():
    assert get_ledger("none") is None
    assert isinstance(get_ledger("hashchain"), HashChainLedger)
    with pytest.raises(KeyError):
        get_ledger("ethereum-mainnet")
