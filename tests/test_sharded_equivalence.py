"""Sharded-vs-single-device equivalence (subprocess: needs forced devices).

Runs tests/sharded_eq_impl.py with XLA_FLAGS=--xla_force_host_platform_device_count=8:
for each reduced arch the shard_map'd train and decode steps must match the
meshless oracle. Validates gather tables, SP attention offsets, EP dispatch +
ring, embedding layouts, distributed softmax, LSE decode combine.
"""
import pathlib
import subprocess
import sys

import pytest

IMPL = pathlib.Path(__file__).parent / "sharded_eq_impl.py"

GROUPS = {
    "dense": "yi-34b",
    "mla_tied": "minicpm3-4b",
    "moe_model_ep": "qwen3-moe-30b-a3b",
    "moe_grid_ep": "arctic-480b",
    "hybrid": "jamba-1.5-large-398b",
    "spatial_encdec": "whisper-base",
    "spatial_ssm": "xlstm-125m",
}


@pytest.mark.slow
@pytest.mark.parametrize("arch", sorted(GROUPS.values()))
def test_sharded_equivalence(arch):
    r = subprocess.run([sys.executable, str(IMPL), arch],
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"\nstdout:\n{r.stdout}\nstderr:\n{r.stderr[-2000:]}"
    assert "MISMATCH" not in r.stdout
