"""Checkpoint/restart, elastic reshard, straggler/fault runtime tests."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt as ckpt_mod
from repro.configs.base import FLConfig, get_config
from repro.core.jobs import load_job
from repro.runtime.executor import Executor
from repro.runtime.faults import FaultModel, select_cohort


def toy_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (32, 8)),
                       "b": jnp.zeros((8,))},
            "server": (), "clients": ()}


def test_checkpoint_roundtrip(tmp_path):
    st = toy_state()
    ckpt_mod.save(tmp_path, 3, st, extra={"next_round": 3},
                  async_write=False)
    assert ckpt_mod.latest_round(tmp_path) == 3
    st2, extra = ckpt_mod.restore(tmp_path, 3, toy_state(seed=1))
    assert extra["next_round"] == 3
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(st2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_keeps_last(tmp_path):
    st = toy_state()
    for r in range(6):
        ckpt_mod.save(tmp_path, r, st, async_write=False, keep_last=2)
    rounds = sorted(p.name for p in tmp_path.glob("round_*"))
    assert len(rounds) == 2
    assert ckpt_mod.latest_round(tmp_path) == 5


def test_elastic_reshard(tmp_path):
    """Restore onto a different device layout (elastic scale)."""
    st = toy_state()
    ckpt_mod.save(tmp_path, 0, st, async_write=False)
    shardings = jax.tree.map(
        lambda t: jax.sharding.SingleDeviceSharding(jax.devices()[0]), st)
    st2, _ = ckpt_mod.restore(tmp_path, 0, st, shardings=shardings)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(st2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# stragglers / faults
# ---------------------------------------------------------------------------

def test_cohort_overprovision_drops_stragglers():
    fault = FaultModel(straggler_prob=0.3, straggler_slowdown=10.0, seed=1)
    ids = np.arange(100)
    kept = select_cohort(fault, 0, ids, target=20, overprovision=1.5)
    assert len(kept) == 20
    # deterministic
    kept2 = select_cohort(fault, 0, ids, target=20, overprovision=1.5)
    np.testing.assert_array_equal(kept, kept2)


def test_cohort_survives_drops():
    fault = FaultModel(drop_prob=0.5, seed=2)
    kept = select_cohort(fault, 0, np.arange(40), target=30,
                         overprovision=1.0)
    assert 0 < len(kept) <= 30


# ---------------------------------------------------------------------------
# executor end-to-end: restart == uninterrupted (fault tolerance)
# ---------------------------------------------------------------------------

JOB = {
    "name": "resume-test",
    "model": {"arch": "flsim-logreg"},
    "dataset": {"dataset": "synthetic_vision", "n_items": 256,
                "distribution": {"partition": "iid"}},
    "strategy": {"strategy": "fedavg",
                 "train_params": {"n_clients": 4, "local_epochs": 1,
                                  "client_lr": 0.1, "rounds": 4,
                                  "checkpoint_every": 1, "seed": 3}},
}


def _dataset_for_logreg(job):
    # logreg expects 784-dim inputs: reuse vision synth with mnist shape
    from repro.data.pipeline import SyntheticVision
    job.dataset = SyntheticVision(n_items=256, shape=(28, 28, 1), seed=3)
    return job


def test_restart_equals_uninterrupted(tmp_path):
    job1 = _dataset_for_logreg(load_job(JOB))
    ex1 = Executor(job1, ckpt_dir=None).scaffold()
    state_full, _ = ex1.run(rounds=4)

    # interrupted run: 2 rounds, then a new executor resumes from disk
    job2 = _dataset_for_logreg(load_job(JOB))
    ex2 = Executor(job2, ckpt_dir=str(tmp_path)).scaffold()
    ex2.run(rounds=2)
    job3 = _dataset_for_logreg(load_job(JOB))
    ex3 = Executor(job3, ckpt_dir=str(tmp_path)).scaffold()
    assert ex3.round_idx == 2, "must resume from the checkpoint"
    state_resumed, _ = ex3.run(rounds=4)

    for a, b in zip(jax.tree.leaves(state_full["params"]),
                    jax.tree.leaves(state_resumed["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_executor_logs_and_ledger(tmp_path):
    job = _dataset_for_logreg(load_job({**JOB, "strategy": {
        "strategy": "fedavg",
        "train_params": {"n_clients": 4, "rounds": 2, "client_lr": 0.1,
                         "blockchain": "hashchain", "seed": 5}}}))
    ex = Executor(job).scaffold()
    state, logger = ex.run(rounds=2)
    assert len(logger.rows) == 2
    assert job.ledger.verify()
    assert len(job.ledger.blocks()) == 3       # genesis + 2 global records
    assert "loss" in logger.rows[-1]
