"""End-to-end behaviour tests for the whole system.

The full FLsim pipeline: job yaml -> orchestrator -> Alg.-1 executor ->
compiled rounds -> ledger/metrics, plus the serve path, on CPU-scale
configs. (Distribution-layer equivalence lives in
test_sharded_equivalence.py; per-substrate tests in their own modules.)
"""
import os

os.environ.setdefault("REPRO_KERNEL_IMPL", "jnp")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.jobs import load_job
from repro.runtime.executor import Executor

# end-to-end system runs (including a forced-device subprocess compile) are
# nightly-tier; CI runs them on the cron, not on every push
pytestmark = pytest.mark.slow


JOB_YAML = """
name: system-test
model:
  arch: flsim-mlp
dataset:
  dataset: synthetic_vision
  n_items: 256
  distribution:
    partition: dirichlet
    dirichlet_alpha: 0.5
strategy:
  strategy: fedavgm
  train_params:
    n_clients: 4
    local_epochs: 1
    client_lr: 0.1
    server_momentum: 0.9
    rounds: 4
    seed: 1
    blockchain: hashchain
runtime:
  straggler_prob: 0.2
  straggler_overprovision: 1.25
"""


def test_job_yaml_to_trained_model(tmp_path):
    """The paper's full workflow: yaml -> scaffold -> rounds -> dashboard."""
    path = tmp_path / "job.yaml"
    path.write_text(JOB_YAML)
    job = load_job(path)
    assert job.strategy.name == "fedavgm"
    assert job.ledger is not None
    ex = Executor(job).scaffold()
    state, logger = ex.run()
    losses = logger.series("loss")
    assert len(losses) == 4
    assert losses[-1] < losses[0], f"no learning: {losses}"
    assert job.ledger.verify()
    # process-phase machine ended in aggregation with all nodes complete
    assert ex.kv.get("process_phase") == 2
    assert ex.kv.all_nodes_in_stage(ex.nodes, 4)
    assert "FL dashboard" in logger.dashboard()


def test_fl_lm_round_with_strategies():
    """Temporal rounds on a reduced LM across three strategies."""
    from repro.configs.base import FLConfig, get_config
    from repro.configs.reduce import reduced_config
    from repro.core import determinism
    from repro.core.rounds import build_temporal_round, init_state
    from repro.core.strategies import get_strategy
    from repro.data.pipeline import SyntheticLM
    from repro.models import model_zoo
    from repro.sharding.axes import AxisCtx

    cfg = reduced_config(get_config("qwen2.5-32b"))
    model = model_zoo.build(cfg)
    lm = SyntheticLM(vocab=cfg.vocab_size, seed=0)
    for name in ("fedavg", "fedavgm", "fedprox"):
        fl = FLConfig(strategy=name, client_lr=0.05, prox_mu=0.01,
                      local_epochs=1, seed=0)
        strategy = get_strategy(fl)
        rf = jax.jit(lambda s, b, w, r: build_temporal_round(
            model, strategy, fl, cfg)(AxisCtx(), s, b, w, r))
        state = init_state(model, strategy, fl, determinism.root_key(0))
        losses = []
        for r in range(3):
            # fixed client data across rounds -> loss must decrease
            batches = [lm.client_batches(c, 2, 2, 32, round_idx=0)
                       for c in (0, 1)]
            batch = jax.tree.map(lambda *t: np.stack(t), *batches)
            state, m = rf(state, batch, jnp.ones((2,)),
                          determinism.round_key(determinism.root_key(0), r))
            losses.append(float(m["loss"]))
        assert all(np.isfinite(losses)), f"{name}: {losses}"
        assert losses[-1] < losses[0], f"{name} diverged: {losses}"


def test_serve_generate_roundtrip():
    """Prefill + N greedy decode steps stay self-consistent."""
    from repro.configs.base import get_config
    from repro.configs.reduce import reduced_config
    from repro.launch.serve import generate
    from repro.models import model_zoo

    cfg = reduced_config(get_config("qwen3-moe-30b-a3b"))
    model = model_zoo.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                 cfg.vocab_size)
    toks = generate(model, params, prompts, max_new=4)
    assert toks.shape == (2, 4)
    assert (np.asarray(toks) >= 0).all()
    assert (np.asarray(toks) < cfg.padded_vocab).all()
    # deterministic
    toks2 = generate(model, params, prompts, max_new=4)
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(toks2))


def test_dryrun_machinery_on_forced_devices():
    """launch.dryrun's collective parser + hlo walker on a real compile."""
    import subprocess
    import sys
    code = (
        "import os;"
        "os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8';"
        "import sys; sys.path.insert(0,'src');"
        "import jax;"
        "from repro.configs.base import get_config, ShapeConfig;"
        "from repro.configs.reduce import reduced_config;"
        "from repro.launch import steps, hlo_cost;"
        "from repro.launch.dryrun import collective_bytes;"
        "from repro.launch.mesh import make_test_mesh;"
        "mesh=make_test_mesh((2,2),('data','model'));"
        "cfg=reduced_config(get_config('yi-34b'));"
        "b=steps.make_step_from_cfg(cfg, ShapeConfig('t',32,8,'train'), mesh);"
        "c=jax.jit(b.fn, donate_argnums=b.donate).lower(*b.inputs).compile();"
        "txt=c.as_text();"
        "cb=collective_bytes(txt);"
        "cost=hlo_cost.analyze(txt);"
        "assert cb['counts'].get('all-gather',0) > 0, cb;"
        "assert cost.flops > 1e6, cost.flops;"
        "assert cost.hbm_bytes > cost.hbm_inner_bytes >= 0;"
        "print('dryrun machinery OK')"
    )
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "dryrun machinery OK" in r.stdout
