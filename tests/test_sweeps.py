"""Campaign subsystem tests (vmapped multi-trajectory sweeps).

The campaign determinism contract extends the driver/async contracts
(tests/test_driver.py, tests/test_async.py) along the sweep axis: lane ``s``
of a vmapped S-trajectory campaign is bitwise identical to an independent
single run of the s-th expanded config — for sync and async modes, across a
seeds x alpha x lr grid — and chunked == unchunked still holds under the
sweep axis. Plus the job-loader satellite: unknown config keys fail loudly
with a near-miss suggestion instead of silently running with defaults.
"""
import os

os.environ.setdefault("REPRO_KERNEL_IMPL", "jnp")

import jax
import numpy as np
import pytest

from repro.core import sweeps
from repro.core.jobs import load_job
from repro.runtime.campaign import CampaignExecutor
from repro.runtime.executor import Executor


def _raw(coord=None, sweep=None, *, mode="sync", strategy="fedavg",
         rounds=3, chunk=3, n_clients=4):
    """One job dict; ``coord`` overrides land in their proper sections (the
    single-run references for each campaign lane are built this way)."""
    coord = coord or {}
    tp = {"n_clients": n_clients, "local_epochs": 1,
          "client_lr": coord.get("client_lr", 0.1),
          "rounds": rounds, "seed": coord.get("seed", 3),
          "rounds_per_launch": chunk,
          "prox_mu": coord.get("prox_mu", 0.0)}
    runtime = {"straggler_prob": 0.2, "straggler_overprovision": 1.25}
    if mode == "async":
        tp.update({"mode": "async", "async_buffer": 3, "max_staleness": 4,
                   "staleness_exponent": coord.get("staleness_exponent",
                                                   0.5)})
        runtime = {"straggler_prob": 0.2, "duration_sigma": 0.25}
    raw = {
        "name": "sweep-test",
        "model": {"arch": "flsim-mlp"},
        "dataset": {"dataset": "synthetic_vision", "n_items": 128,
                    "distribution": {
                        "partition": "dirichlet",
                        "dirichlet_alpha": coord.get("dirichlet_alpha",
                                                     0.5)}},
        "strategy": {"strategy": strategy, "train_params": tp},
        "runtime": runtime,
    }
    if sweep:
        raw["sweep"] = sweep
    return raw


def _assert_bitwise_equal(p1, p2):
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _assert_lanes_match_singles(camp, mk_raw):
    for s, coord in enumerate(camp.spec.coords()):
        state, _ = Executor(load_job(mk_raw(coord))).scaffold().run()
        _assert_bitwise_equal(jax.tree.map(np.asarray, state["params"]),
                              camp.trajectory_params(s))


# ---------------------------------------------------------------------------
# the campaign determinism contract
# ---------------------------------------------------------------------------

def test_sync_campaign_bitwise_equals_single_runs():
    """S=8 seeds x alpha x lr grid, one vmapped launch == 8 independent
    Executor runs, bitwise (data plane + scalar plane together)."""
    sweep = {"seeds": [3, 5], "dirichlet_alpha": [0.3, 3.0],
             "client_lr": [0.05, 0.1]}
    camp = CampaignExecutor(load_job(_raw(sweep=sweep))).scaffold()
    camp.run()
    assert camp.S == 8
    _assert_lanes_match_singles(camp, lambda c: _raw(c))


def test_async_campaign_bitwise_equals_single_runs():
    """Async (FedBuff) campaign: seeds x staleness_exponent x lr — per-lane
    schedules (seed + staleness discount are host-plane) and traced lr."""
    sweep = {"seeds": [7, 9], "staleness_exponent": [0.0, 1.0],
             "client_lr": [0.05, 0.1]}
    camp = CampaignExecutor(
        load_job(_raw({"seed": 7}, sweep=sweep, mode="async",
                      chunk=2))).scaffold()
    camp.run()
    assert camp.S == 8
    _assert_lanes_match_singles(
        camp, lambda c: _raw(c, mode="async", chunk=2))


def test_compressed_campaign_bitwise_equals_single_runs():
    """The packed int8 path under the campaign vmap: lanes must stay
    bitwise their single runs (quantize -> quant_aggregate -> server
    update per round), and the aggregation must actually route through
    the kernels/ops dispatcher inside the vmapped trace."""
    from repro.kernels import ops

    def mk(coord=None):
        raw = _raw(coord, strategy="compressed")
        raw["strategy"]["train_params"].update(
            {"compression": "int8", "error_feedback": True})
        return raw

    sweep = {"seeds": [3, 5], "client_lr": [0.05, 0.1]}
    raw = mk()
    raw["sweep"] = sweep
    jax.clear_caches()
    ops.reset_quant_agg_stats()
    camp = CampaignExecutor(load_job(raw)).scaffold()
    camp.run()
    assert camp.S == 4
    assert ops.quant_agg_stats()["calls"] > 0, \
        "campaign aggregation bypassed the kernel dispatcher"
    _assert_lanes_match_singles(camp, mk)


def test_compression_is_a_categorical_sweep_axis():
    """A compression axis buckets by program signature (dense vs packed
    aggregation are different traced programs) — it must parse, expand,
    and land in the categorical plane, with typos caught."""
    spec = sweeps.parse_sweep({"compression": ["none", "int8", "topk"]})
    assert spec.size == 3 and spec.categorical_names == ("compression",)
    from repro.configs.base import FLConfig
    assert [f.compression for f in sweeps.expand(FLConfig(), spec)] == \
        ["none", "int8", "topk"]
    with pytest.raises(KeyError, match="int8"):
        sweeps.parse_sweep({"compression": ["int9"]})


def test_fedprox_mu_sweep_bitwise():
    """The scalar plane reaches strategy hooks: swept prox_mu through
    FedProx's local_loss, bitwise vs single runs."""
    sweep = {"prox_mu": [0.0, 0.1]}
    camp = CampaignExecutor(
        load_job(_raw(sweep=sweep, strategy="fedprox"))).scaffold()
    camp.run()
    _assert_lanes_match_singles(
        camp, lambda c: _raw(c, strategy="fedprox"))


@pytest.mark.parametrize("mode", ["sync", "async"])
def test_chunked_equals_unchunked_under_sweep(mode):
    """rounds_per_launch chunking must stay bitwise-invariant with the
    sweep axis vmapped on top (uneven 2+1 chunking included)."""
    sweep = {"seeds": [3, 5], "client_lr": [0.05, 0.1]}
    runs = {}
    for chunk in (1, 3, 2):
        camp = CampaignExecutor(
            load_job(_raw(sweep=sweep, mode=mode, chunk=chunk))).scaffold()
        camp.run()
        runs[chunk] = jax.tree.map(np.asarray, camp.state["params"])
    _assert_bitwise_equal(runs[1], runs[3])
    _assert_bitwise_equal(runs[1], runs[2])


def test_async_schedule_plane_dedup_bitwise():
    """Scalar-only async sweeps used to duplicate the (E,) event schedule S
    times the way data used to be duplicated (the ROADMAP schedule-plane
    item): lanes sharing (seed, partition, alpha, staleness_exponent) must
    share ONE schedule on device — and stay bitwise their single runs (the
    strongest form of "dedup changed nothing")."""
    sweep = {"client_lr": [0.05, 0.1, 0.2]}
    camp = CampaignExecutor(
        load_job(_raw(sweep=sweep, mode="async", chunk=2))).scaffold()
    assert camp.S == 3
    # one unique schedule serves all three lanes
    assert camp.sched_dev["client"].shape[0] == 1
    np.testing.assert_array_equal(camp.lane_sched, [0, 0, 0])
    assert camp.schedules[0] is camp.schedules[2]
    camp.run()
    _assert_lanes_match_singles(
        camp, lambda c: _raw(c, mode="async", chunk=2))


def test_async_schedule_plane_dedup_keys():
    """Mixed sweep: the schedule dedups per distinct (seed,
    staleness_exponent) while the swept lr rides along — U=4 schedules for
    S=8 lanes, keyed row-major like the data plane."""
    sweep = {"seeds": [7, 9], "staleness_exponent": [0.0, 1.0],
             "client_lr": [0.05, 0.1]}
    camp = CampaignExecutor(
        load_job(_raw({"seed": 7}, sweep=sweep, mode="async",
                      chunk=2))).scaffold()
    assert camp.S == 8
    assert camp.sched_dev["client"].shape[0] == 4
    np.testing.assert_array_equal(camp.lane_sched, [0, 0, 1, 1, 2, 2, 3, 3])


# ---------------------------------------------------------------------------
# sweep expansion / config surface
# ---------------------------------------------------------------------------

def test_sweep_grid_expansion_row_major():
    spec = sweeps.parse_sweep({"seeds": [0, 1], "client_lr": [0.1, 0.2]})
    assert spec.size == 4 and spec.names == ("seed", "client_lr")
    assert spec.coords() == [
        {"seed": 0, "client_lr": 0.1}, {"seed": 0, "client_lr": 0.2},
        {"seed": 1, "client_lr": 0.1}, {"seed": 1, "client_lr": 0.2}]
    from repro.configs.base import FLConfig
    fls = sweeps.expand(FLConfig(), spec)
    assert [f.seed for f in fls] == [0, 0, 1, 1]
    hyper = sweeps.scalar_plane(fls)
    np.testing.assert_array_equal(np.asarray(hyper["seed"]), [0, 0, 1, 1])
    np.testing.assert_allclose(np.asarray(hyper["client_lr"]),
                               [0.1, 0.2, 0.1, 0.2])
    # unswept sweepable scalars broadcast the base value
    np.testing.assert_allclose(np.asarray(hyper["server_lr"]), [1.0] * 4)


def test_sweep_unknown_axis_near_miss():
    with pytest.raises(KeyError, match="client_lr"):
        sweeps.parse_sweep({"cleint_lr": [0.1]})
    with pytest.raises(ValueError, match="non-empty"):
        sweeps.parse_sweep({"seeds": []})
    with pytest.raises(ValueError, match="duplicates"):
        sweeps.parse_sweep({"seeds": [0, 1], "seed": [2, 3]})
    assert sweeps.parse_sweep(None) is None


def test_campaign_resume_keeps_full_results_table(tmp_path):
    """Checkpoint + resume must not truncate campaign.csv: the table is
    rewritten at chunk boundaries and re-adopted on restore, so the resumed
    run's table covers every round."""
    sweep = {"seeds": [3, 5]}

    def mk(out):
        raw = _raw(sweep=sweep, chunk=2)
        raw["strategy"]["train_params"]["rounds"] = 4
        raw["strategy"]["train_params"]["checkpoint_every"] = 2
        return CampaignExecutor(load_job(raw), out_dir=str(out),
                                ckpt_dir=str(tmp_path / "ckpt"))

    full = CampaignExecutor(
        load_job({**_raw(sweep=sweep, chunk=2),
                  "strategy": {"strategy": "fedavg", "train_params": {
                      **_raw(sweep=sweep)["strategy"]["train_params"],
                      "rounds": 4, "rounds_per_launch": 2}}})).scaffold()
    full.run()

    ex = mk(tmp_path / "a").scaffold()
    ex.run(rounds=2)                     # crash after the first chunk
    ex2 = mk(tmp_path / "a").scaffold()  # resumes at round 2
    assert ex2.round_idx == 2 and len(ex2.results) == 2 * 2
    ex2.run()
    assert sorted({r["round"] for r in ex2.results}) == [0, 1, 2, 3]
    assert len(ex2.results) == 2 * 4
    _assert_bitwise_equal(jax.tree.map(np.asarray, full.state["params"]),
                          jax.tree.map(np.asarray, ex2.state["params"]))


def test_campaign_resume_rejects_changed_grid(tmp_path):
    """A checkpoint records the campaign's real lane count: resuming with a
    different sweep grid must fail loudly instead of silently adopting
    lane states whose coordinates belong to the old grid (only the device
    padding is elastic)."""

    def mk(sweep):
        raw = _raw(sweep=sweep, chunk=2)
        raw["strategy"]["train_params"]["rounds"] = 4
        raw["strategy"]["train_params"]["checkpoint_every"] = 2
        return CampaignExecutor(load_job(raw),
                                ckpt_dir=str(tmp_path / "ckpt"))

    mk({"seeds": [3, 5, 7, 9]}).scaffold().run(rounds=2)
    with pytest.raises(ValueError, match="different sweep grid"):
        mk({"seeds": [3, 5]}).scaffold()          # fewer lanes
    with pytest.raises(ValueError, match="different sweep grid"):
        mk({"seeds": [11, 13, 17, 19]}).scaffold()  # same S, other coords


def test_campaign_curves_grouping_immune_to_eval_columns():
    """rounds_per_launch=1 puts eval metrics on every row; the curve
    grouping must still key on sweep axes only (one curve per lr)."""
    from benchmarks.figures import campaign_curves
    sweep = {"seeds": [3, 5], "client_lr": [0.05, 0.1]}
    camp = CampaignExecutor(load_job(_raw(sweep=sweep, chunk=1))).scaffold()
    camp.eval_fn = lambda params: {
        "acc": float(sum(np.abs(np.asarray(t)).sum()
                         for t in jax.tree.leaves(params)))}
    camp.run()
    out = campaign_curves(camp.results)
    assert len(out) == 2
    assert all(len(c["rounds"]) == 3 for c in out)


def test_load_job_rejects_unknown_top_level_section():
    raw = _raw()
    raw["runtim"] = raw.pop("runtime")
    with pytest.raises(KeyError, match="runtime"):
        load_job(raw)


def test_campaign_ledger_records_per_lane_digests():
    """Blockchain-enabled campaigns must keep per-run provenance: each
    lane's params digest (== the single run's, by the bitwise contract)
    must be findable in the chain."""
    from repro.core.blockchain import param_digest
    raw = _raw(sweep={"seeds": [3, 5]})
    raw["strategy"]["train_params"]["blockchain"] = "hashchain"
    camp = CampaignExecutor(load_job(raw)).scaffold()
    camp.run()
    assert camp.job.ledger.verify()
    for s in range(camp.S):
        dig = param_digest(camp.trajectory_params(s))
        assert camp.job.ledger.provenance(dig), f"lane {s} not in ledger"


def test_campaign_results_table(tmp_path):
    """Tidy table: one row per (trajectory, round) keyed by the sweep
    coordinates; per-lane eval merges into each trajectory's last row."""
    sweep = {"seeds": [3, 5], "client_lr": [0.05, 0.1]}
    camp = CampaignExecutor(load_job(_raw(sweep=sweep)),
                            out_dir=str(tmp_path)).scaffold()
    camp.eval_fn = lambda params: {
        "pnorm": float(sum(np.abs(np.asarray(t)).sum()
                           for t in jax.tree.leaves(params)))}
    camp.run()
    assert len(camp.results) == camp.S * 3
    row = camp.results[0]
    assert {"seed", "client_lr", "traj", "round", "loss"} <= set(row)
    # eval lands on each lane's final-round row, with per-lane values
    tails = [r for r in camp.results if r["round"] == 2]
    assert len(tails) == camp.S and all("pnorm" in r for r in tails)
    assert len({r["pnorm"] for r in tails}) > 1
    csv_path = camp.write_results()
    assert csv_path.exists()
    header = csv_path.read_text().splitlines()[0].split(",")
    assert header[:4] == ["seed", "client_lr", "traj", "round"]


# ---------------------------------------------------------------------------
# job loader validation (no silent key drops)
# ---------------------------------------------------------------------------

def test_load_job_rejects_unknown_keys_with_near_miss():
    raw = _raw()
    raw["strategy"]["train_params"]["cleint_lr"] = 0.5
    del raw["strategy"]["train_params"]["client_lr"]
    with pytest.raises(KeyError, match="client_lr"):
        load_job(raw)

    raw = _raw()
    raw["runtime"]["stragler_prob"] = 0.5
    with pytest.raises(KeyError, match="straggler_prob"):
        load_job(raw)

    raw = _raw()
    raw["dataset"]["distribution"]["dirichlet_alpa"] = 1.0
    with pytest.raises(KeyError, match="dirichlet_alpha"):
        load_job(raw)
