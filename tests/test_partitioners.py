"""Partitioner property tests (paper component 3: Dataset Distributor).

Every ``partition`` kind must be a disjoint exact cover of the root indices
and a pure function of its seed; Dirichlet heterogeneity must fall as alpha
grows; and the resample loop must be bounded (a tiny alpha with many
clients used to hang forever).
"""
import numpy as np
import pytest

from repro.data.partition import (dirichlet_partition, heterogeneity,
                                  partition)


def _labels(n=600, n_classes=10, seed=0):
    return np.random.RandomState(seed).randint(0, n_classes, n)


@pytest.mark.parametrize("kind", ["iid", "dirichlet", "shards"])
@pytest.mark.parametrize("n_clients", [1, 4, 13])
def test_partition_is_disjoint_exact_cover(kind, n_clients):
    labels = _labels()
    parts = partition(kind, labels, n_clients, alpha=0.5, seed=7)
    assert len(parts) == n_clients
    flat = np.concatenate([p for p in parts if len(p)])
    assert len(flat) == len(labels), "partition must cover every item"
    assert len(np.unique(flat)) == len(flat), "partitions must be disjoint"
    np.testing.assert_array_equal(np.sort(flat), np.arange(len(labels)))


@pytest.mark.parametrize("kind", ["iid", "dirichlet", "shards"])
def test_partition_deterministic_in_seed(kind):
    labels = _labels()
    a = partition(kind, labels, 8, alpha=0.5, seed=3)
    b = partition(kind, labels, 8, alpha=0.5, seed=3)
    for pa, pb in zip(a, b):
        np.testing.assert_array_equal(pa, pb)
    c = partition(kind, labels, 8, alpha=0.5, seed=4)
    assert any(not np.array_equal(pa, pc) for pa, pc in zip(a, c)), \
        f"{kind}: different seeds should give different partitions"


def test_dirichlet_heterogeneity_decreases_with_alpha():
    labels = _labels(n=2000)
    het = {alpha: heterogeneity(
        dirichlet_partition(labels, 10, alpha, seed=0), labels)
        for alpha in (0.1, 10.0)}
    assert het[0.1] > het[10.0], \
        f"alpha=0.1 must be more heterogeneous than 10.0, got {het}"
    assert het[10.0] < 0.2, "alpha=10 should be near-IID"


def test_dirichlet_resample_is_bounded():
    """n_items < n_clients * min_size is unsatisfiable: the retry loop must
    raise a clear error naming the settings instead of hanging forever."""
    labels = _labels(n=10, n_classes=2)
    with pytest.raises(ValueError) as e:
        dirichlet_partition(labels, 8, alpha=0.01, seed=0, min_size=2)
    msg = str(e.value)
    assert "alpha=0.01" in msg and "n_clients=8" in msg and "100" in msg


def test_dirichlet_first_draw_unchanged_by_retry_bound():
    """The bounded loop must keep the original RNG stream: a satisfiable
    draw returns exactly what the unbounded loop used to."""
    labels = _labels(n=400)
    a = dirichlet_partition(labels, 4, 0.5, seed=11)
    b = dirichlet_partition(labels, 4, 0.5, seed=11, max_retries=1)
    for pa, pb in zip(a, b):
        np.testing.assert_array_equal(pa, pb)
