"""Strategy unit + property tests (hypothesis on the aggregation invariants)."""
import os

os.environ.setdefault("REPRO_KERNEL_IMPL", "jnp")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.base import FLConfig
from repro.core.strategies import REGISTRY, get_strategy
from repro.core.strategy import Strategy, tree_sub
from repro.core.topology import ClientServer, Decentralized, Hierarchical
from repro.sharding.axes import AxisCtx

CTX = AxisCtx()


def toy_params(seed=0, n=64):
    k = jax.random.PRNGKey(seed)
    a, b = jax.random.split(k)
    return {"w": jax.random.normal(a, (n,)), "b": jax.random.normal(b, (4,))}


# ---------------------------------------------------------------------------
# aggregation properties
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(2, 8), st.integers(1, 1000))
def test_weighted_mean_linearity_and_permutation(n_clients, seed):
    rng = np.random.RandomState(seed)
    deltas = {"w": jnp.asarray(rng.randn(n_clients, 16), jnp.float32)}
    w = jnp.asarray(rng.rand(n_clients) + 0.1, jnp.float32)
    topo = ClientServer()
    agg = topo.aggregate(CTX, deltas, w)
    want = np.average(np.asarray(deltas["w"]), axis=0, weights=np.asarray(w))
    np.testing.assert_allclose(np.asarray(agg["w"]), want, rtol=1e-5, atol=1e-6)
    # permutation invariance
    perm = rng.permutation(n_clients)
    agg2 = topo.aggregate(CTX, {"w": deltas["w"][perm]}, w[perm])
    np.testing.assert_allclose(np.asarray(agg2["w"]), want, rtol=1e-5,
                               atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8), st.integers(1, 100))
def test_hierarchical_equals_flat_for_equal_weights(n_clients, seed):
    rng = np.random.RandomState(seed)
    deltas = {"w": jnp.asarray(rng.randn(n_clients, 8), jnp.float32)}
    w = jnp.ones((n_clients,), jnp.float32)
    flat = ClientServer().aggregate(CTX, deltas, w)
    hier = Hierarchical().aggregate(CTX, deltas, w)
    np.testing.assert_allclose(np.asarray(flat["w"]), np.asarray(hier["w"]),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(3, 12), st.integers(1, 100), st.integers(1, 4))
def test_gossip_preserves_mean_and_contracts(n_clients, seed, steps):
    """Doubly-stochastic mixing: mean invariant, variance non-increasing."""
    rng = np.random.RandomState(seed)
    state = {"w": jnp.asarray(rng.randn(n_clients, 8), jnp.float32)}
    topo = Decentralized(gossip_steps=steps)
    mixed = topo.mix(CTX, state)
    np.testing.assert_allclose(np.asarray(mixed["w"]).mean(0),
                               np.asarray(state["w"]).mean(0),
                               rtol=1e-4, atol=1e-5)
    assert np.asarray(mixed["w"]).var(0).sum() <= \
        np.asarray(state["w"]).var(0).sum() + 1e-5


# ---------------------------------------------------------------------------
# per-strategy behaviour
# ---------------------------------------------------------------------------

def test_registry_complete():
    fl = FLConfig()
    for name in REGISTRY:
        s = get_strategy(FLConfig(strategy=name))
        assert isinstance(s, Strategy)


def test_fedavgm_momentum_accumulates():
    fl = FLConfig(strategy="fedavgm", server_momentum=0.5, server_lr=1.0)
    s = get_strategy(fl)
    p = toy_params()
    st_ = s.server_state_init(p)
    d = jax.tree.map(jnp.ones_like, p)
    p1, st_ = s.server_update(p, d, st_)
    p2, st_ = s.server_update(p1, d, st_)
    # second step moves further (momentum): dp2 = 1.5, dp1 = 1.0
    dp1 = np.asarray(p1["w"] - p["w"])
    dp2 = np.asarray(p2["w"] - p1["w"])
    np.testing.assert_allclose(dp1, 1.0, rtol=1e-5)
    np.testing.assert_allclose(dp2, 1.5, rtol=1e-5)


def test_fedprox_penalizes_drift():
    fl = FLConfig(strategy="fedprox", prox_mu=10.0)
    s = get_strategy(fl)
    p_far = toy_params(1)
    g = toy_params(0)

    def base(params, batch, rng):
        return jnp.zeros(()), {}

    l_far, _ = s.local_loss(base, p_far, g, None, (), None)
    l_same, _ = s.local_loss(base, g, g, None, (), None)
    assert float(l_far) > float(l_same) + 1e-3
    assert abs(float(l_same)) < 1e-6


def test_scaffold_correction_and_cstate():
    fl = FLConfig(strategy="scaffold", client_lr=0.1)
    s = get_strategy(fl)
    p = toy_params()
    sst = s.server_state_init(p)
    cst = s.client_state_init(p)
    g = jax.tree.map(jnp.ones_like, p)
    # with zero control variates the gradient is unchanged
    g2 = s.grad_transform(g, cst, sst)
    np.testing.assert_allclose(np.asarray(g2["w"]), np.asarray(g["w"]))
    # after an update with drift, c_i changes by -delta/(K*lr)
    delta = jax.tree.map(lambda t: -0.1 * t, g)   # one sgd step of lr .1
    cst2 = s.client_state_update(cst, sst, delta, 1, 0.1)
    np.testing.assert_allclose(np.asarray(cst2["c_i"]["w"]), 1.0, rtol=1e-5)


def test_dp_clipping_bounds_norm():
    fl = FLConfig(strategy="dp_fedavg", dp_clip=1.0, dp_noise=0.0)
    s = get_strategy(fl)
    d = {"w": jnp.full((100,), 10.0)}
    out, _ = s.postprocess(d, (), jax.random.PRNGKey(0))
    nrm = float(jnp.linalg.norm(out["w"]))
    assert nrm <= 1.0 + 1e-4


def test_dp_noise_scales():
    fl = FLConfig(strategy="dp_fedavg", dp_clip=1.0, dp_noise=0.5)
    s = get_strategy(fl)
    d = {"w": jnp.zeros((10_000,))}
    out, _ = s.postprocess(d, (), jax.random.PRNGKey(0))
    std = float(jnp.std(out["w"]))
    assert abs(std - 0.5) < 0.05


@pytest.mark.parametrize("comp", ["int8", "topk"])
def test_compression_error_feedback_recovers(comp):
    """With error feedback, repeated identical deltas converge: residual
    carries the quantization error forward."""
    fl = FLConfig(strategy="compressed", compression=comp, topk_ratio=0.2,
                  error_feedback=True)
    s = get_strategy(fl)
    p = toy_params()
    cst = s.client_state_init(p)
    true_delta = jax.tree.map(lambda t: 0.01 * jnp.sign(t), p)
    sent_total = jax.tree.map(jnp.zeros_like, p)
    for _ in range(8):
        sent, cst = s.postprocess(true_delta, cst, jax.random.PRNGKey(0))
        sent_total = jax.tree.map(lambda a, b: a + b, sent_total, sent)
    want = jax.tree.map(lambda t: 8 * t, true_delta)
    err = max(float(jnp.abs(a - b).max())
              for a, b in zip(jax.tree.leaves(sent_total),
                              jax.tree.leaves(want)))
    assert err < 0.015, f"error feedback failed to recover: {err}"


def test_topk_mask_exact_k_under_ties():
    """All-equal magnitudes tie at the k-th value: a threshold compare
    would keep everything; the scatter mask must keep exactly k."""
    from repro.core.strategies.compressed import _topk_mask
    x = jnp.ones((100,))
    mask = _topk_mask(x, 0.2)
    assert int(mask.sum()) == 20
    # blocks of repeated values around the cut: still exactly k survive
    y = jnp.repeat(jnp.asarray([3.0, 2.0, 2.0, 1.0]), 25)
    mask = _topk_mask(y, 0.3)
    assert int(mask.sum()) == 30


def test_topk_postprocess_keeps_exact_budget():
    fl = FLConfig(strategy="compressed", compression="topk", topk_ratio=0.1,
                  error_feedback=False)
    s = get_strategy(fl)
    d = {"w": jnp.ones((200,))}          # every element ties
    sent, _ = s.postprocess(d, {}, jax.random.PRNGKey(0))
    assert int((sent["w"] != 0).sum()) == 20


def test_packed_int8_matches_roundtrip_path():
    """The packed emission (what quant_aggregate consumes) must be the
    same quantization the unpacked ``_roundtrip_int8`` send models:
    per-leaf padding keeps block boundaries identical, so dequantized
    sends AND error-feedback residuals agree bitwise across the two
    representations of the same compression."""
    from repro.core import packing
    fl = FLConfig(strategy="compressed", compression="int8",
                  error_feedback=True)
    s = get_strategy(fl)
    assert s.packs_deltas
    p = toy_params(n=300)                # w: 300 floats -> pads to 512
    delta = jax.tree.map(lambda t: 0.1 * t, p)
    rng = jax.random.PRNGKey(0)

    sent_ref, cst_ref = s.postprocess(delta, s.client_state_init(p), rng)
    pd, cst_pk = s.postprocess_packed(delta, s.client_state_init(p), rng)
    sent_pk = packing.unpack_tree(packing.dequant_flat(pd), delta)
    for a, b in zip(jax.tree.leaves(sent_ref), jax.tree.leaves(sent_pk)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(cst_ref["residual"]),
                    jax.tree.leaves(cst_pk["residual"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_packing_roundtrip_identity():
    """pack -> unpack is the identity on any float pytree (padding is
    sliced off per leaf), and packed_size reports the padded layout."""
    from repro.core import packing
    p = toy_params(n=300)
    n, nblocks = packing.packed_size(p)
    assert n == nblocks * packing.QBLOCK
    flat = packing.pack_tree(p)
    assert flat.shape == (n,)
    back = packing.unpack_tree(flat, p)
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b))


def test_moon_contrastive_term_positive():
    fl = FLConfig(strategy="moon", moon_mu=1.0, moon_tau=0.5)
    s = get_strategy(fl)
    p = toy_params(2)
    g = toy_params(0)
    cst = {"prev_local": tree_sub(p, g)}

    def base(params, batch, rng):
        return jnp.zeros(()), {}

    l, _ = s.local_loss(base, p, g, None, cst, None)
    assert float(l) > 0.0
