"""Flight recorder tests (repro/telemetry) + observability satellites.

The load-bearing contract is *bitwise invariance*: the recorder is pure
host-side bookkeeping, so running any driver (sync, async, campaign) with
telemetry on must produce bit-identical params to the same run with it off.
On top of that: span nesting is deterministic (IDs in open order, events in
close order — structure reconstructs from (id, parent, depth) with no
timestamp tie-breaks), the JSONL stream round-trips, the Chrome-trace
export is Perfetto-shaped (M/X/C events, one pid per track, time
containment on a shared tid), and the report collates a
compile/execute/stage/io breakdown. Satellites: ``PerformanceLogger.to_csv``
without out_dir, ``ru_maxrss`` platform units, scoped quant-agg counters,
and the job-loader's telemetry-section validation.
"""
import json
import os

os.environ.setdefault("REPRO_KERNEL_IMPL", "jnp")

import jax
import numpy as np
import pytest

from repro.core.jobs import load_job
from repro.kernels import ops as kernel_ops
from repro.metrics import logger as logger_mod
from repro.metrics.logger import PerformanceLogger
from repro.runtime.campaign import CampaignExecutor
from repro.runtime.executor import Executor
from repro.telemetry.recorder import FlightRecorder, read_events
from repro.telemetry.trace import export, report, to_chrome_trace


def _raw(*, mode="sync", rounds=4, chunk=2, sweep=None, telemetry=None,
         seed=3):
    tp = {"n_clients": 4, "local_epochs": 1, "client_lr": 0.1,
          "rounds": rounds, "seed": seed, "rounds_per_launch": chunk}
    runtime = {"straggler_prob": 0.2, "straggler_overprovision": 1.25}
    if mode == "async":
        tp.update({"mode": "async", "async_buffer": 3, "max_staleness": 4,
                   "staleness_exponent": 0.5})
        runtime = {"straggler_prob": 0.2, "duration_sigma": 0.25}
    raw = {
        "name": "telemetry-test",
        "model": {"arch": "flsim-mlp"},
        "dataset": {"dataset": "synthetic_vision", "n_items": 128,
                    "distribution": {"partition": "dirichlet",
                                     "dirichlet_alpha": 0.5}},
        "strategy": {"strategy": "fedavg", "train_params": tp},
        "runtime": runtime,
    }
    if sweep:
        raw["sweep"] = sweep
    if telemetry is not None:
        raw["telemetry"] = telemetry
    return raw


def _params(state):
    return jax.tree.map(np.asarray, state["params"])


def _assert_bitwise_equal(p1, p2):
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# satellites: logger fixes
# ---------------------------------------------------------------------------

def test_to_csv_explicit_path_without_out_dir(tmp_path):
    """out_dir=None + explicit path works; no path at all fails loudly
    (it used to crash with TypeError deep in pathlib)."""
    lg = PerformanceLogger()
    lg.log_round(0, loss=1.0)
    out = lg.to_csv(tmp_path / "run.csv")
    assert out.exists()
    rows = out.read_text().splitlines()
    assert len(rows) == 2 and "loss" in rows[0]
    with pytest.raises(ValueError, match="explicit path"):
        lg.to_csv()


def test_rss_mb_platform_units(monkeypatch):
    """ru_maxrss is KB on Linux but BYTES on macOS — the same 512 MiB peak
    must read 512 on both."""
    monkeypatch.setattr(logger_mod.sys, "platform", "linux")
    assert logger_mod._rss_mb(512 * 1024) == 512.0
    monkeypatch.setattr(logger_mod.sys, "platform", "darwin")
    assert logger_mod._rss_mb(512 * 2**20) == 512.0


def test_host_usage_keys():
    u = logger_mod.host_usage()
    assert set(u) == {"cpu_s", "max_rss_mb"}
    assert u["cpu_s"] > 0 and u["max_rss_mb"] > 0


# ---------------------------------------------------------------------------
# satellite: scoped quant-agg counters
# ---------------------------------------------------------------------------

def test_quant_agg_scope_isolates_and_nests():
    kernel_ops.reset_quant_agg_stats()
    kernel_ops._quant_agg_bump("calls")
    assert kernel_ops.quant_agg_stats()["calls"] == 1
    with kernel_ops.quant_agg_scope() as outer:
        kernel_ops._quant_agg_bump("calls")
        with kernel_ops.quant_agg_scope() as inner:
            kernel_ops._quant_agg_bump("calls")
            # innermost frame is the live snapshot view
            assert kernel_ops.quant_agg_stats()["calls"] == 1
        assert inner["calls"] == 1
        assert outer["calls"] == 2          # increments propagate outward
        assert kernel_ops.quant_agg_stats()["calls"] == 2
    # the process-global frame saw everything (legacy semantics outside
    # any scope: reset + read keep working as before)
    assert kernel_ops.quant_agg_stats()["calls"] == 3
    kernel_ops.reset_quant_agg_stats()
    assert kernel_ops.quant_agg_stats()["calls"] == 0


# ---------------------------------------------------------------------------
# recorder core: determinism, round-trip, disabled path
# ---------------------------------------------------------------------------

def _record_fixture(rec):
    with rec.span("scaffold"):
        with rec.span("stage_data"):
            pass
        with rec.span("init_state"):
            pass
    with rec.span("chunk", start=0, n=2):
        with rec.span("launch", ordinal=0) as sp:
            sp.attrs.update(compile_delta=1)
        with rec.span("finish_chunk"):
            pass
    rec.counter("staged_bytes", data_plane=1024, scalar_plane=64)


def _structure(events):
    return [(e["id"], e["parent"], e["depth"], e["name"], e["track"])
            for e in events if e["kind"] == "span"]


def test_span_structure_deterministic():
    """Two identical recordings agree on every structural field — nesting
    reconstructs from (id, parent, depth), never from timestamps."""
    recs = [FlightRecorder(), FlightRecorder()]
    for rec in recs:
        _record_fixture(rec)
    s1, s2 = _structure(recs[0].events), _structure(recs[1].events)
    assert s1 == s2
    # the fixture's shape: scaffold(2 children) then chunk(2 children)
    assert s1[0] == (1, 0, 1, "stage_data", "run")
    assert [n for (_, _, _, n, _) in s1] == [
        "stage_data", "init_state", "scaffold",
        "launch", "finish_chunk", "chunk"]   # close order, parents last
    launch = next(e for e in recs[0].events
                  if e.get("name") == "launch")
    assert launch["attrs"] == {"ordinal": 0, "compile_delta": 1}


def test_jsonl_roundtrip(tmp_path):
    rec = FlightRecorder(out_dir=tmp_path, run_name="rt")
    _record_fixture(rec)
    rec.close()
    events = read_events(tmp_path)
    assert events[0]["kind"] == "meta"
    assert events[0]["run"] == "rt" and events[0]["schema"] == 1
    assert events[1:] == rec.events          # file == memory, in order
    with pytest.raises(FileNotFoundError, match="telemetry.jsonl"):
        read_events(tmp_path / "nope")


def test_disabled_recorder_is_inert(tmp_path):
    rec = FlightRecorder(out_dir=tmp_path, enabled=False)
    with rec.span("launch") as sp:
        sp.attrs.update(ignored=True)        # null span discards updates
        rec.counter("host", cpu_s=1.0)
    assert rec.events == []
    assert not (tmp_path / "telemetry.jsonl").exists()


def test_from_job_section_gates_recorder(tmp_path):
    on = FlightRecorder.from_job(
        load_job(_raw(telemetry={"out_dir": str(tmp_path)})))
    off = FlightRecorder.from_job(load_job(_raw()))
    killed = FlightRecorder.from_job(
        load_job(_raw(telemetry={"enabled": False,
                                 "out_dir": str(tmp_path)})))
    assert on.enabled and str(on.out_dir) == str(tmp_path)
    assert not off.enabled and not killed.enabled


# ---------------------------------------------------------------------------
# Chrome-trace export shape
# ---------------------------------------------------------------------------

def test_chrome_trace_shape():
    rec = FlightRecorder()
    _record_fixture(rec)
    rec.counter("lane_occupancy", track="bucket0", alive=3, total=4)
    tr = to_chrome_trace(rec.events)
    assert set(tr) == {"traceEvents", "displayTimeUnit"}
    evs = tr["traceEvents"]
    by_ph = {}
    for e in evs:
        by_ph.setdefault(e["ph"], []).append(e)
    # one process_name + one thread_name metadata pair per track
    names = {e["args"]["name"] for e in by_ph["M"]
             if e["name"] == "process_name"}
    assert names == {"run", "bucket0"}
    # every span is a complete event with its own duration
    assert len(by_ph["X"]) == 6
    for e in by_ph["X"]:
        assert e["tid"] == 1 and e["dur"] >= 0 and "ts" in e
    # children are time-contained in their parent (what Perfetto nests on)
    x = {e["args"]["span_id"]: e for e in by_ph["X"]}
    spans = {e["id"]: e for e in rec.events if e["kind"] == "span"}
    for sid, ev in spans.items():
        if ev["parent"] is not None:
            par = x[ev["parent"]]
            assert par["ts"] <= x[sid]["ts"]
            assert x[sid]["ts"] + x[sid]["dur"] <= par["ts"] + par["dur"]
    # counters keep only numeric values
    assert {e["name"] for e in by_ph["C"]} == {"staged_bytes",
                                               "lane_occupancy"}


# ---------------------------------------------------------------------------
# bitwise on/off invariance — all three drivers
# ---------------------------------------------------------------------------

def test_sync_bitwise_with_telemetry(tmp_path):
    s_off, _ = Executor(load_job(_raw())).scaffold().run()
    ex = Executor(load_job(_raw(
        telemetry={"out_dir": str(tmp_path)}))).scaffold()
    s_on, _ = ex.run()
    _assert_bitwise_equal(_params(s_off), _params(s_on))
    names = {e["name"] for e in ex.recorder.events if e["kind"] == "span"}
    assert {"scaffold", "stage_data", "init_state", "chunk", "launch",
            "finish_chunk"} <= names
    launches = [e for e in ex.recorder.events if e.get("name") == "launch"]
    assert len(launches) == 2                # 4 rounds / chunk=2
    assert launches[0]["attrs"]["compile_delta"] >= 1    # cold
    assert launches[1]["attrs"]["compile_delta"] == 0    # warm
    assert (tmp_path / "telemetry.jsonl").exists()


def test_async_bitwise_with_telemetry(tmp_path):
    s_off, _ = Executor(load_job(_raw(mode="async"))).scaffold().run()
    ex = Executor(load_job(_raw(
        mode="async", telemetry={"out_dir": str(tmp_path)}))).scaffold()
    s_on, _ = ex.run()
    _assert_bitwise_equal(_params(s_off), _params(s_on))
    names = {e["name"] for e in ex.recorder.events if e["kind"] == "span"}
    assert "build_schedule" in names
    planes = next(e for e in ex.recorder.events
                  if e.get("name") == "staged_bytes")
    assert planes["values"]["schedule_plane"] > 0


def test_campaign_bitwise_with_telemetry(tmp_path):
    sweep = {"seeds": [3, 5]}
    c_off = CampaignExecutor(load_job(_raw(sweep=sweep))).scaffold()
    c_off.run()
    c_on = CampaignExecutor(load_job(_raw(
        sweep=sweep, telemetry={"out_dir": str(tmp_path)}))).scaffold()
    c_on.run()
    for s in range(2):
        _assert_bitwise_equal(c_off.trajectory_params(s),
                              c_on.trajectory_params(s))
    launches = [e for e in c_on.recorder.events if e.get("name") == "launch"]
    assert launches and all(
        e["attrs"]["n_alive"] == 2 and e["attrs"]["S"] == 2
        for e in launches)
    occ = [e for e in c_on.recorder.events
           if e.get("name") == "lane_occupancy"]
    assert occ and occ[-1]["values"] == {"alive": 2, "total": 2}
    quant = next(e for e in c_on.recorder.events
                 if e.get("name") == "quant_agg")
    assert quant["values"]["calls"] == 0     # fedavg float path


# ---------------------------------------------------------------------------
# export + report end-to-end, per-bucket tracks under the planner
# ---------------------------------------------------------------------------

def test_export_and_report_end_to_end(tmp_path):
    ex = Executor(load_job(_raw(
        telemetry={"out_dir": str(tmp_path)}))).scaffold()
    ex.run()
    ex.recorder.close()
    trace_path = export(tmp_path)
    tr = json.loads(trace_path.read_text())
    assert any(e["ph"] == "X" and e["name"] == "launch"
               for e in tr["traceEvents"])
    text = report(tmp_path)
    for word in ("compile", "execute", "stage", "telemetry-test",
                 "launches"):
        assert word in text


def test_plan_executor_per_bucket_tracks(tmp_path):
    from repro.runtime.scheduler import PlanExecutor
    sweep = {"strategy": ["fedavg", "fedprox"], "seeds": [3, 5]}
    pe = PlanExecutor(load_job(_raw(
        sweep=sweep, rounds=2,
        telemetry={"out_dir": str(tmp_path / "t")})),
        out_dir=str(tmp_path / "out")).scaffold()
    pe.run()
    pe.recorder.close()
    events = read_events(tmp_path / "t")
    tracks = {e.get("track") for e in events} - {None}
    assert {"bucket0", "bucket1", "plan"} <= tracks
    # one shared recorder: bucket spans interleave in one id space
    ids = [e["id"] for e in events if e.get("kind") == "span"]
    assert len(ids) == len(set(ids))
    assert any(e.get("name") == "table_flush" and e["track"] == "plan"
               for e in events)
    tr = to_chrome_trace(events)
    procs = {e["args"]["name"] for e in tr["traceEvents"]
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert {"bucket0", "bucket1", "plan"} <= procs


# ---------------------------------------------------------------------------
# satellite: job-loader telemetry section validation
# ---------------------------------------------------------------------------

def test_telemetry_section_typo_fails_with_hint():
    with pytest.raises(KeyError, match="did you mean 'out_dir'"):
        load_job(_raw(telemetry={"out_dirr": "/tmp/x"}))
    with pytest.raises(KeyError, match="telemetry"):
        load_job(dict(_raw(), telemetryy={"out_dir": "/tmp/x"}))
