"""Multi-worker aggregation with a byzantine worker + blockchain audit trail.

Replicates the paper's RQ3/RQ4 story end to end: three redundant workers
(one malicious), majority-digest consensus (the "smart contract"), and a
hash-chain ledger recording aggregate digests, consensus decisions, worker
reputations and global-model provenance.

  PYTHONPATH=src python examples/byzantine_consensus.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig, get_config
from repro.core import determinism
from repro.core.blockchain import HashChainLedger, param_digest
from repro.core.consensus import MultiWorkerAggregator, poison
from repro.core.rounds import build_spatial_round, init_state
from repro.core.strategies import get_strategy
from repro.data.pipeline import SyntheticVision
from repro.models import model_zoo
from repro.sharding.axes import AxisCtx


def main():
    fl = FLConfig(strategy="fedavg", n_clients=6, local_epochs=1,
                  client_lr=0.1, n_workers=3, byzantine_workers=1,
                  consensus="majority_digest", blockchain="hashchain",
                  seed=0)
    cfg = get_config("flsim-mlp")
    model = model_zoo.build(cfg)
    strategy = get_strategy(fl)
    ledger = HashChainLedger()
    round_fn = jax.jit(lambda s, b, w, r: build_spatial_round(
        model, strategy, fl)(AxisCtx(), s, b, w, r))
    data = SyntheticVision(n_items=384, seed=0)
    x, y, parts = data.distribute_into_chunks("dirichlet", fl.n_clients, 0.5)
    state = init_state(model, strategy, fl, determinism.root_key(0),
                       n_clients_local=fl.n_clients)
    root = determinism.root_key(0)
    for r in range(4):
        bs = [SyntheticVision.client_batches(x, y, parts[c], 16, 1,
                                             seed=c + 101 * r)[0]
              for c in range(fl.n_clients)]
        batch = jax.tree.map(lambda *t: np.stack(t), *bs)
        w = jnp.ones((fl.n_clients,), jnp.float32)
        state, m = round_fn(state, batch, w, determinism.round_key(root, r))
        # ledger: record each worker's (possibly poisoned) digest + decision
        good = param_digest(state["params"])
        digests = {}
        for wk in range(fl.n_workers):
            if wk < fl.byzantine_workers:
                digests[f"worker_{wk}"] = param_digest(
                    poison(state["params"], 3.0))
            else:
                digests[f"worker_{wk}"] = good
            ledger.record_aggregate(r, f"worker_{wk}", state["params"])
        ledger.record_consensus(r, "majority_digest", good, digests)
        ledger.record_global(r, state["params"])
        print(f"round {r}: loss {float(m['loss']):.4f} "
              f"global digest {good[:12]}…")
    assert ledger.verify(), "chain must verify"
    print("\nworker reputations:", {k: round(v, 2)
                                    for k, v in ledger.reputation.items()})
    prov = ledger.provenance(param_digest(state["params"]))
    print(f"provenance of final model: {len(prov)} block(s); "
          f"chain length {len(ledger.blocks())}; verified=True")


if __name__ == "__main__":
    main()
