"""Quickstart: the paper's core loop in ~30 lines of public API.

Defines an FL job (paper Fig. 2 sections as a dict), scaffolds it through
the Job Orchestrator, runs FedAvg over Dirichlet-partitioned clients with
the Logic-Controller executor, and prints the FL dashboard.

  PYTHONPATH=src python examples/quickstart.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core.jobs import load_job
from repro.runtime.executor import Executor

JOB = {
    "name": "quickstart",
    "model": {"arch": "flsim-cnn"},
    "dataset": {
        "dataset": "synthetic_vision",
        "n_items": 512,
        "distribution": {"partition": "dirichlet", "dirichlet_alpha": 0.5},
    },
    "strategy": {
        "strategy": "fedavg",
        # rounds_per_launch=5 fuses all 5 rounds into ONE compiled launch
        # (lax.scan); batches + cohorts are derived on device, the host only
        # sees the chunk boundary. placement can be "temporal" to run one
        # client at a time over the whole mesh instead.
        "train_params": {"n_clients": 8, "local_epochs": 2,
                         "client_lr": 0.05, "rounds": 5, "seed": 0,
                         "rounds_per_launch": 5, "placement": "spatial"},
    },
    "runtime": {"straggler_prob": 0.1, "straggler_overprovision": 1.25},
}


def main():
    job = load_job(JOB)
    # scale the CNN for CPU quickness (same as the benches)
    job.model = job.model.__class__(job.model.cfg.replace(d_model=32, d_ff=64),
                                    job.model.kind)
    ex = Executor(job).scaffold()

    def eval_fn(params):
        x, y, _ = ex.data
        import jax.numpy as jnp
        return {"accuracy": job.model.accuracy(
            params, {"x": jnp.asarray(x[:256]), "y": jnp.asarray(y[:256])})}

    ex.eval_fn = eval_fn
    state, logger = ex.run()
    print(logger.dashboard())
    assert logger.rows[-1]["loss"] < logger.rows[0]["loss"]
    print("quickstart OK")


if __name__ == "__main__":
    main()
