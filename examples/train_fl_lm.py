"""End-to-end driver: federated training of an LM architecture.

Runs temporal FL rounds (cohort scanned over the mesh — the same round
program the multi-pod dry-run compiles) on a synthetic Markov token stream,
with checkpointing and restart. Default is a CPU-sized model; --scale 100m
selects a ~100M-parameter config (the deliverable-(b) setting — budget a few
hours of CPU, or minutes on a real pod).

  PYTHONPATH=src python examples/train_fl_lm.py --arch yi-34b --rounds 30
"""
import argparse
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt as ckpt_mod
from repro.configs.base import FLConfig, get_config
from repro.configs.reduce import reduced_config
from repro.core import determinism
from repro.core.rounds import build_temporal_round, init_state
from repro.core.strategies import get_strategy
from repro.data.pipeline import SyntheticLM
from repro.metrics.logger import PerformanceLogger
from repro.models import model_zoo
from repro.sharding.axes import AxisCtx

SCALES = {
    # (d_model, n_layers, d_ff, vocab) — heads stay at the reduced config's
    "tiny": (64, 2, 128, 512),
    "10m": (256, 4, 1024, 2048),
    "100m": (640, 10, 2560, 8192),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-34b")
    ap.add_argument("--scale", default="tiny", choices=sorted(SCALES))
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--cohort", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--local-epochs", type=int, default=2)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--strategy", default="fedavgm")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    d, L, f, v = SCALES[args.scale]
    cfg = reduced_config(get_config(args.arch)).replace(
        d_model=d, d_ff=f, vocab_size=v)
    if cfg.family not in ("hybrid", "ssm"):
        cfg = cfg.replace(n_layers=L)
    model = model_zoo.build(cfg)
    n_params = sum(int(np.prod(s)) for s in jax.tree.leaves(
        model.shapes(), is_leaf=lambda x: isinstance(x, tuple)))
    print(f"arch={cfg.name} scale={args.scale}: {n_params/1e6:.1f}M params")

    fl = FLConfig(strategy=args.strategy, n_clients=args.clients,
                  local_epochs=args.local_epochs, client_lr=0.05,
                  server_momentum=0.9, seed=0)
    strategy = get_strategy(fl)
    ctx = AxisCtx()
    round_fn = jax.jit(lambda s, b, w, r: build_temporal_round(
        model, strategy, fl, cfg)(ctx, s, b, w, r))
    state = init_state(model, strategy, fl, determinism.root_key(0))
    start_round = 0
    if args.ckpt_dir:
        last = ckpt_mod.latest_round(args.ckpt_dir)
        if last is not None:
            state, extra = ckpt_mod.restore(args.ckpt_dir, last, state)
            start_round = extra["next_round"]
            print(f"resumed from round {start_round}")

    lm = SyntheticLM(vocab=cfg.vocab_size, seed=0)
    logger = PerformanceLogger(run_name=f"fl-lm-{args.arch}-{args.scale}")
    root = determinism.root_key(0)
    for r in range(start_round, args.rounds):
        cohort = [(r * 13 + i) % args.clients for i in range(args.cohort)]
        batches = [lm.client_batches(c, args.local_steps, args.batch,
                                     args.seq, round_idx=r)
                   for c in cohort]
        batch = jax.tree.map(lambda *t: np.stack(t), *batches)
        w = jnp.ones((len(cohort),), jnp.float32)
        t0 = time.time()
        state, m = round_fn(state, batch, w, determinism.round_key(root, r))
        logger.log_round(r, loss=float(m["loss"]),
                         round_s=time.time() - t0)
        if r % 5 == 0 or r == args.rounds - 1:
            print(f"round {r:4d} loss {float(m['loss']):.4f} "
                  f"({time.time()-t0:.1f}s)", flush=True)
        if args.ckpt_dir and (r + 1) % 10 == 0:
            ckpt_mod.save(args.ckpt_dir, r + 1, state,
                          extra={"next_round": r + 1}, async_write=False)
    print(logger.dashboard())
    first, last = logger.rows[0]["loss"], logger.rows[-1]["loss"]
    print(f"loss {first:.4f} -> {last:.4f}")
    assert last < first, "FL training must reduce loss"


if __name__ == "__main__":
    main()
