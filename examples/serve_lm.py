"""Serving example: batched prefill + greedy decode of an FL-trained model.

  PYTHONPATH=src python examples/serve_lm.py --arch minicpm3-4b
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.launch.serve import main

if __name__ == "__main__":
    main()
