"""Decentralized (Fedstellar-style) FL: no server, torus gossip mixing.

Shows per-client models diverging during local training and re-contracting
through gossip; reports the consensus distance ||theta_i - mean|| per round.

  PYTHONPATH=src python examples/decentralized_gossip.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig, get_config
from repro.core import determinism
from repro.core.rounds import build_spatial_round, init_state
from repro.core.strategies import get_strategy
from repro.data.pipeline import SyntheticVision
from repro.models import model_zoo
from repro.sharding.axes import AxisCtx


def divergence(params):
    tot, n = 0.0, 0
    for leaf in jax.tree.leaves(params):
        mean = leaf.mean(0, keepdims=True)
        tot += float(jnp.sum((leaf - mean) ** 2))
        n += leaf[0].size
    return (tot / max(n, 1)) ** 0.5


def main():
    fl = FLConfig(strategy="gossip", topology="decentralized", n_clients=8,
                  local_epochs=2, client_lr=0.05, gossip_steps=1, seed=0)
    cfg = get_config("flsim-mlp")
    model = model_zoo.build(cfg)
    strategy = get_strategy(fl)
    round_fn = jax.jit(lambda s, b, w, r: build_spatial_round(
        model, strategy, fl)(AxisCtx(), s, b, w, r))
    data = SyntheticVision(n_items=512, seed=0)
    x, y, parts = data.distribute_into_chunks("dirichlet", fl.n_clients, 0.5)
    state = init_state(model, strategy, fl, determinism.root_key(0),
                       n_clients_local=fl.n_clients, decentralized=True)
    test = {"x": jnp.asarray(x[:256]), "y": jnp.asarray(y[:256])}
    root = determinism.root_key(0)
    for r in range(6):
        bs = [SyntheticVision.client_batches(x, y, parts[c], 16, 1,
                                             seed=c + 31 * r)[0]
              for c in range(fl.n_clients)]
        batch = jax.tree.map(lambda *t: np.stack(t), *bs)
        w = jnp.ones((fl.n_clients,), jnp.float32)
        state, m = round_fn(state, batch, w, determinism.round_key(root, r))
        mean_params = jax.tree.map(lambda t: t.mean(0), state["params"])
        acc = float(model.accuracy(mean_params, test))
        print(f"round {r}: loss {float(m['loss']):.4f}  "
              f"mean-model acc {acc:.3f}  divergence {divergence(state['params']):.2e}")
    print("gossip OK")


if __name__ == "__main__":
    main()
