"""Roofline analysis (deliverable g): three terms per (arch x shape) cell.

For each cell, compiles the single-pod step, walks the compiled HLO with the
exact cost model (launch/hlo_cost.py — while-loop trip counts multiplied),
and reports per chip:

  compute_s    = HLO_dot_flops / PEAK_FLOPS_BF16
  memory_s     = post-fusion HBM bytes / HBM_BW
  collective_s = per-chip collective traffic / ICI_BW

plus MODEL_FLOPS = 6*N*D (train) / 2*N*D (prefill) / 2*N_active*B (decode),
the useful-compute ratio, the dominant term and a one-line lever.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--arch ...] [--tag t]
Writes results/roofline/<cell>.json and prints the table.
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse
import json
import pathlib
import sys
import time

import jax

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.configs.base import ARCHS, SHAPES, get_config, shapes_for
from repro.launch import hlo_cost
from repro.launch import mesh as mesh_mod
from repro.launch import steps as steps_mod
from repro.models import model_zoo

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "roofline"

PEAK = mesh_mod.PEAK_FLOPS_BF16
HBM = mesh_mod.HBM_BW
ICI = mesh_mod.ICI_BW


def model_flops(cfg, shape) -> float:
    """Useful-model flops for the whole step, all chips (6ND / 2ND / 2N_a*B)."""
    N = model_zoo.count_params(cfg)
    Na = model_zoo.count_params(cfg, active_only=True)
    toks = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * Na * toks
    if shape.kind == "prefill":
        return 2.0 * Na * toks
    return 2.0 * Na * shape.global_batch      # decode: one token per sequence


def run_cell(arch: str, shape_name: str, multi_pod=False, tag=""):
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    t0 = time.time()
    built = steps_mod.make_step_from_cfg(cfg, shape, mesh)
    with jax.set_mesh(mesh):
        compiled = jax.jit(built.fn, donate_argnums=built.donate) \
            .lower(*built.inputs).compile()
    ma = compiled.memory_analysis()
    cost = hlo_cost.analyze(compiled.as_text())
    compute_s = cost.flops / PEAK
    memory_s = cost.hbm_bytes / HBM
    # kernelized floor: inner-loop (attention/ssm/ring) intermediates live in
    # VMEM inside the Pallas kernels on TPU — see hlo_cost.Cost.
    memory_kernel_s = (cost.hbm_bytes - cost.hbm_inner_bytes) / HBM
    coll_bytes = sum(cost.coll_traffic.values())
    collective_s = coll_bytes / ICI
    mf = model_flops(cfg, shape)
    hlo_total = cost.flops * n_chips
    terms = {"compute_s": compute_s, "memory_s": memory_kernel_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound_time = max(terms.values())
    # roofline fraction: useful model time / achievable bound time
    model_time = mf / (n_chips * PEAK)
    frac = model_time / bound_time if bound_time else 0.0
    rec = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": int(n_chips),
        **{k: round(v, 6) for k, v in terms.items()},
        "memory_raw_s": round(memory_s, 6),
        "dominant": dominant,
        "hlo_flops_per_chip": cost.flops,
        "hbm_bytes_per_chip": cost.hbm_bytes,
        "collective_bytes_per_chip": coll_bytes,
        "coll_by_kind": {k: round(v) for k, v in cost.coll_traffic.items()},
        "coll_counts": {k: round(v) for k, v in cost.coll_counts.items()},
        "model_flops": mf,
        "useful_ratio": round(mf / hlo_total, 4) if hlo_total else 0.0,
        "roofline_fraction": round(frac, 4),
        "memory_peak_GiB": round((ma.argument_size_in_bytes +
                                  ma.temp_size_in_bytes) / 2**30, 2),
        "fits_hbm16": bool((ma.argument_size_in_bytes +
                            ma.temp_size_in_bytes) / 2**30 <= 16.0),
        "compile_s": round(time.time() - t0, 1),
    }
    return rec


LEVERS = {
    "compute_s": "already compute-bound: raise MFU via larger matmul tiles / "
                 "fewer recompute passes (remat policy)",
    "memory_s": "memory-bound: fuse elementwise chains, cast f32 "
                "intermediates to bf16, cut activation round-trips",
    "collective_s": "collective-bound: overlap gathers with compute "
                    "(prefetch next layer), shrink payloads (int8), or "
                    "re-shard to reduce traffic",
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    RESULTS.mkdir(parents=True, exist_ok=True)
    rows = []
    for arch in archs:
        names = shapes_for(arch) if args.shape == "all" else args.shape.split(",")
        for shape_name in names:
            if shape_name not in shapes_for(arch):
                continue
            try:
                rec = run_cell(arch, shape_name, tag=args.tag)
            except Exception as e:  # noqa
                print(f"FAIL {arch} x {shape_name}: {e!r}", flush=True)
                continue
            rows.append(rec)
            key = f"{arch}__{shape_name}"
            if args.tag:
                key += f"__{args.tag}"
            (RESULTS / f"{key}.json").write_text(json.dumps(rec, indent=1))
            print(f"{arch:24s} {shape_name:12s} "
                  f"C {rec['compute_s']*1e3:9.2f}ms "
                  f"M {rec['memory_s']*1e3:9.2f}ms "
                  f"(raw {rec['memory_raw_s']*1e3:9.2f}) "
                  f"X {rec['collective_s']*1e3:9.2f}ms "
                  f"dom={rec['dominant'][:4]} "
                  f"useful={rec['useful_ratio']:.2f} "
                  f"roof={rec['roofline_fraction']:.2f} "
                  f"mem={rec['memory_peak_GiB']:.1f}G", flush=True)
    return rows


if __name__ == "__main__":
    main()
