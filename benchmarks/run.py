"""Benchmark entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. The roofline table (our §Perf
artifact) is appended from cached dry-run results when present.

Usage: PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig8,...]
"""
import argparse
import json
import os
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))


def roofline_table():
    res = pathlib.Path(__file__).resolve().parents[1] / "results" / "roofline"
    rows = []
    if not res.exists():
        print("roofline,0,run `python -m benchmarks.roofline` first")
        return rows
    for f in sorted(res.glob("*.json")):
        r = json.loads(f.read_text())
        name = f"roofline_{r['arch']}_{r['shape']}"
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        print(f"{name},{bound*1e6:.0f},"
              f"dom={r['dominant']};useful={r['useful_ratio']};"
              f"roof={r['roofline_fraction']};mem_GiB={r['memory_peak_GiB']}")
        rows.append(r)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer rounds / smaller sizes")
    ap.add_argument("--only", default="all")
    args = ap.parse_args()
    selected = (args.only != "all") and args.only.split(",")
    if selected == ["shard"] and \
            "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        # the shard bench needs a multi-device host; on CPU that means
        # forcing fake devices BEFORE jax initializes (imported below).
        # The flag only multiplies the *cpu* platform, so pin the backend
        # too or an accelerator host would ignore the forcing entirely.
        # Only when shard is the SOLE selection: forcing would silently
        # re-platform any co-selected bench onto fake CPU devices, so a
        # mixed selection must bring its own environment (bench_shard's
        # RuntimeError says how).
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                                   " --xla_force_host_platform_device_count=4"
                                   ).strip()
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
    from benchmarks import figures, flbench
    import jax
    q = args.quick
    jobs = {
        # --quick keeps the flsim_small config shape (the host-overhead
        # share depends on it) and only cuts the timed rounds
        "driver": lambda: flbench.bench_driver(rounds=10 if q else 20),
        "async": lambda: flbench.bench_async(
            events=64 if q else 256, chunk_events=16 if q else 64),
        # S=8 seeds vmapped vs sequential; --quick keeps S (the speedup is
        # the claim) and only cuts the timed rounds
        "sweep": lambda: flbench.bench_sweep(rounds=8 if q else 16),
        # heterogeneous strategy x seed grid, bucketed-vmap vs sequential;
        # --quick keeps the grid (bucketing is the claim), cuts the rounds
        "plan": lambda: flbench.bench_plan(rounds=8 if q else 16),
        # S=16 seed grid sharded over a 4-lane device mesh vs 1-device
        # vmap; --quick keeps S and the mesh (the speedup is the claim).
        # Selecting it explicitly forces 4 fake CPU devices (above) and
        # fails hard if they still aren't there (preset XLA_FLAGS /
        # JAX_PLATFORMS can defeat the forcing); only under the implicit
        # "all" does a short host skip it, so the other benches still run.
        "shard": lambda: (
            flbench.bench_shard(rounds=8 if q else 16, reps=3 if q else 4)
            if selected or jax.device_count() >= 4 else
            print("shard,0,skipped: needs 4 devices — run `benchmarks.run "
                  "--only shard` (it forces fake CPU devices itself)")),
        # fused int8 dequant+weighted-sum vs dequant-first materialize at
        # the memory-bound 1M-param scale; --quick keeps the shape (the
        # traffic ratio is the claim) and only cuts the timed reps
        "agg": lambda: flbench.bench_agg(reps=10 if q else 30),
        # flight-recorder overhead at chunk=1 (worst case: a boundary per
        # round); --quick keeps the S=8 grid and cuts rounds/reps. Also
        # writes the telemetry_smoke/ trace artifacts CI uploads
        "telemetry": lambda: flbench.bench_telemetry(
            rounds=8 if q else 16, reps=3 if q else 4),
        # round-probe + recorder overhead at chunk=1 (worst case: drain at
        # every boundary); --quick keeps the S=8 grid and cuts rounds/reps.
        # Also writes the probes_smoke/ trace + probes.csv CI uploads
        "probes": lambda: flbench.bench_probes(
            rounds=8 if q else 16, reps=3 if q else 4),
        # comms-observatory + recorder overhead at chunk=1 (worst case: the
        # host accountants + drain run at every boundary); --quick keeps
        # the S=8 grid and cuts rounds/reps. Also writes the comms_smoke/
        # trace + comms.csv CI uploads
        "comms": lambda: flbench.bench_comms(
            rounds=8 if q else 16, reps=3 if q else 4),
        # streaming vs resident slab staging throughput (the double
        # buffer must hide the host assembly), plus the 10^5-client
        # population working-set demo; --quick keeps the cohort geometry
        # (the overlap is the claim) and cuts rounds + the population
        "stream": lambda: flbench.bench_stream(
            rounds=8 if q else 16, reps=2 if q else 3,
            population=20_000 if q else 100_000),
        "fig8": lambda: figures.fig8_frameworks(rounds=4 if q else 8),
        "fig9": lambda: figures.fig9_agnosticism(rounds=4 if q else 8),
        "fig10": lambda: figures.fig10_multiworker(rounds=3 if q else 6),
        "fig11": lambda: figures.fig11_topologies(rounds=4 if q else 8),
        "tab12": lambda: figures.tab12_reproducibility(rounds=3 if q else 5),
        "fig12": lambda: figures.fig12_scale(
            rounds=2 if q else 3, sizes=(100, 250) if q else
            (100, 250, 500, 1000)),
        "roofline": roofline_table,
    }
    only = selected or list(jobs)
    print("name,us_per_call,derived")
    for name in only:
        jobs[name]()


if __name__ == "__main__":
    main()
