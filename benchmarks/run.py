"""Benchmark entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. The roofline table (our §Perf
artifact) is appended from cached dry-run results when present.

Usage: PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig8,...]
"""
import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))


def roofline_table():
    res = pathlib.Path(__file__).resolve().parents[1] / "results" / "roofline"
    rows = []
    if not res.exists():
        print("roofline,0,run `python -m benchmarks.roofline` first")
        return rows
    for f in sorted(res.glob("*.json")):
        r = json.loads(f.read_text())
        name = f"roofline_{r['arch']}_{r['shape']}"
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        print(f"{name},{bound*1e6:.0f},"
              f"dom={r['dominant']};useful={r['useful_ratio']};"
              f"roof={r['roofline_fraction']};mem_GiB={r['memory_peak_GiB']}")
        rows.append(r)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer rounds / smaller sizes")
    ap.add_argument("--only", default="all")
    args = ap.parse_args()
    from benchmarks import figures, flbench
    q = args.quick
    jobs = {
        # --quick keeps the flsim_small config shape (the host-overhead
        # share depends on it) and only cuts the timed rounds
        "driver": lambda: flbench.bench_driver(rounds=10 if q else 20),
        "async": lambda: flbench.bench_async(
            events=64 if q else 256, chunk_events=16 if q else 64),
        # S=8 seeds vmapped vs sequential; --quick keeps S (the speedup is
        # the claim) and only cuts the timed rounds
        "sweep": lambda: flbench.bench_sweep(rounds=8 if q else 16),
        # heterogeneous strategy x seed grid, bucketed-vmap vs sequential;
        # --quick keeps the grid (bucketing is the claim), cuts the rounds
        "plan": lambda: flbench.bench_plan(rounds=8 if q else 16),
        "fig8": lambda: figures.fig8_frameworks(rounds=4 if q else 8),
        "fig9": lambda: figures.fig9_agnosticism(rounds=4 if q else 8),
        "fig10": lambda: figures.fig10_multiworker(rounds=3 if q else 6),
        "fig11": lambda: figures.fig11_topologies(rounds=4 if q else 8),
        "tab12": lambda: figures.tab12_reproducibility(rounds=3 if q else 5),
        "fig12": lambda: figures.fig12_scale(
            rounds=2 if q else 3, sizes=(100, 250) if q else
            (100, 250, 500, 1000)),
        "roofline": roofline_table,
    }
    only = list(jobs) if args.only == "all" else args.only.split(",")
    print("name,us_per_call,derived")
    for name in only:
        jobs[name]()


if __name__ == "__main__":
    main()
