"""Shared driver for the paper-replication benchmarks (Figs. 8-12, Tabs 1-2).

Paper settings (CIFAR-10, 3-conv CNN, 10 clients, Dirichlet alpha=0.5,
batch 64, lr 1e-3, 30 rounds) are scaled to CPU-minutes: synthetic
CIFAR-shaped data, reduced channel counts, fewer rounds — the *relative*
comparisons the figures make are preserved. Every run reports accuracy,
loss, wall time, and simulated communication bytes per round.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig, get_config
from repro.core import determinism
from repro.core.rounds import build_spatial_round, init_state
from repro.core.strategies import get_strategy
from repro.data.pipeline import SyntheticVision
from repro.models import model_zoo
from repro.metrics.logger import PerformanceLogger
from repro.sharding.axes import AxisCtx


def comm_bytes_per_round(params, fl: FLConfig) -> float:
    """Simulated network bytes/round — delegates to the comms observatory's
    closed-form byte model (``core/netmodel.round_nbytes``): exact
    dense/int8/topk payload sizes, gossip neighbour exchanges, consensus
    sharing + digest votes, ledger block records. Full participation; the
    mask-gated per-round accounting lives in ``netmodel.LaneComms``."""
    from repro.core.netmodel import round_nbytes
    return float(round_nbytes(params, fl))


def bench_driver(arch: str = "flsim-mlp", n_clients: int = 16,
                 rounds: int = 20, chunks=(1, 10), n_items: int = 512,
                 seed: int = 0, out_path: str = "BENCH_driver.json"):
    """Rounds/sec for the device-resident multi-round driver, chunked vs
    unchunked, on a paper-scale (flsim_small) CPU config.

    For each chunk size the same Executor path runs ``rounds`` rounds after a
    warm-up launch (compile excluded). Because chunked and unchunked runs are
    bitwise-identical by the driver contract, the delta is pure host+dispatch
    overhead; ``host_overhead_frac`` = the fraction of the unchunked
    per-round wall time that chunking eliminates. Writes ``out_path`` and
    prints one CSV row per chunk size.
    """
    import json

    from repro.core.jobs import load_job
    from repro.runtime.executor import Executor

    assert chunks[0] == 1, \
        "chunks must start with 1 (the speedup/overhead baselines are " \
        "defined vs unchunked execution)"
    assert all(rounds % c == 0 for c in chunks), \
        "rounds must be a multiple of every chunk size (keeps the timed " \
        "region free of remainder-length compiles)"

    results = {"config": {"arch": arch, "n_clients": n_clients,
                          "rounds": rounds, "n_items": n_items,
                          "seed": seed, "backend": jax.default_backend()},
               "runs": {}}
    for chunk in chunks:
        job = load_job({
            "name": f"bench-driver-c{chunk}",
            "model": {"arch": arch},
            "dataset": {"dataset": "synthetic_vision", "n_items": n_items,
                        "distribution": {"partition": "dirichlet",
                                         "dirichlet_alpha": 0.5}},
            "strategy": {"strategy": "fedavg",
                         "train_params": {"n_clients": n_clients,
                                          "local_epochs": 1,
                                          "client_lr": 0.1,
                                          "rounds": rounds + chunk,
                                          "seed": seed,
                                          "rounds_per_launch": chunk}},
        })
        ex = Executor(job).scaffold()
        ex.run(rounds=chunk)                      # warm-up: compile + stage
        t0 = time.time()
        ex.run(rounds=chunk + rounds)
        dt = time.time() - t0
        results["runs"][str(chunk)] = {"rounds": rounds, "wall_s": dt,
                                       "rounds_per_s": rounds / dt,
                                       "s_per_round": dt / rounds}
    runs = results["runs"]
    base = runs[str(chunks[0])]
    for chunk in chunks:
        r = runs[str(chunk)]
        r["speedup_vs_chunk1"] = r["rounds_per_s"] / base["rounds_per_s"]
        r["host_overhead_frac"] = max(
            0.0, 1.0 - r["s_per_round"] / base["s_per_round"])
        print(f"driver_chunk{chunk},{r['s_per_round']*1e6:.0f},"
              f"rounds_per_s={r['rounds_per_s']:.2f};"
              f"speedup={r['speedup_vs_chunk1']:.2f};"
              f"host_overhead={r['host_overhead_frac']:.2f}")
    if out_path:
        with open(out_path, "w") as f:
            json.dump(results, f, indent=2)
    return results


def bench_async(arch: str = "flsim-mlp", n_clients: int = 16,
                events: int = 256, chunk_events: int = 64,
                n_items: int = 512, seed: int = 0,
                out_path: str = "BENCH_async.json"):
    """Events/sec for the event-driven async subsystem, chunked vs
    per-event, on a paper-scale (flsim_small) CPU config.

    The same compiled event-scan body runs the same ``events`` server
    events two ways: one launch per event (the host-loop rendering of an
    async server) and ``chunk_events`` events fused per launch (the
    device-resident rendering). By the async determinism contract both
    trajectories are bitwise-identical, so the delta is pure host+dispatch
    overhead. Writes ``out_path`` and prints one CSV row per granularity.
    """
    import json

    from repro.core.async_rounds import async_init_state, build_async_multi
    from repro.core.jobs import load_job
    from repro.core.rounds import init_state
    from repro.data.pipeline import stage_partitions
    from repro.runtime.clock import build_schedule
    from repro.sharding.axes import AxisCtx

    assert events % chunk_events == 0, \
        "events must be a multiple of chunk_events (keeps the timed " \
        "region free of remainder-length compiles)"
    job = load_job({
        "name": "bench-async",
        "model": {"arch": arch},
        "dataset": {"dataset": "synthetic_vision", "n_items": n_items,
                    "distribution": {"partition": "dirichlet",
                                     "dirichlet_alpha": 0.5}},
        "strategy": {"strategy": "fedavg",
                     "train_params": {"n_clients": n_clients,
                                      "client_lr": 0.1, "seed": seed,
                                      "mode": "async", "async_buffer": 8,
                                      "staleness_exponent": 0.5,
                                      "max_staleness": 8}},
        "runtime": {"straggler_prob": 0.1, "duration_sigma": 0.25},
    })
    fl = job.fl
    x, y, parts = job.dataset.distribute_into_chunks(
        fl.partition, fl.n_clients, fl.dirichlet_alpha)
    staged = stage_partitions(x, y, parts)
    warm = chunk_events
    sched = build_schedule(job.fault, fl.n_clients, warm + events,
                           np.asarray(staged["len"], np.float32),
                           buffer_size=fl.async_buffer,
                           staleness_exponent=fl.staleness_exponent,
                           max_staleness=fl.max_staleness)
    sched_dev = sched.device_arrays()
    multi = build_async_multi(job.model, job.strategy, fl)
    root = determinism.root_key(fl.seed)
    state0 = async_init_state(
        init_state(job.model, job.strategy, fl, root), sched.ring)

    def timed(n_per_launch: int) -> float:
        prog = jax.jit(lambda s, start, n=n_per_launch:
                       multi(AxisCtx(), s, staged, sched_dev, root, start, n))
        state = state0
        for e0 in range(0, warm, n_per_launch):   # warm-up: compile + stage
            state, _ = prog(state, e0)
        state = jax.block_until_ready(state)
        t0 = time.time()
        for e0 in range(warm, warm + events, n_per_launch):
            state, _ = prog(state, e0)
        jax.block_until_ready(state)
        return time.time() - t0

    results = {"config": {"arch": arch, "n_clients": n_clients,
                          "events": events, "chunk_events": chunk_events,
                          "n_items": n_items, "seed": seed,
                          "async_buffer": fl.async_buffer,
                          "backend": jax.default_backend()},
               "runs": {}}
    for n in (1, chunk_events):
        dt = timed(n)
        results["runs"][str(n)] = {"events": events, "wall_s": dt,
                                   "events_per_s": events / dt,
                                   "s_per_event": dt / events}
    base = results["runs"]["1"]
    for n in (1, chunk_events):
        r = results["runs"][str(n)]
        r["speedup_vs_per_event"] = r["events_per_s"] / base["events_per_s"]
        print(f"async_chunk{n},{r['s_per_event']*1e6:.0f},"
              f"events_per_s={r['events_per_s']:.2f};"
              f"speedup={r['speedup_vs_per_event']:.2f}")
    if out_path:
        with open(out_path, "w") as f:
            json.dump(results, f, indent=2)
    return results


def bench_sweep(arch: str = "flsim-logreg", n_traj: int = 8,
                n_clients: int = 8, rounds: int = 16, chunk: int = 1,
                n_items: int = 512, seed: int = 0,
                out_path: str = "BENCH_sweep.json"):
    """Trajectory-rounds/sec for a multi-seed campaign, vmapped vs
    sequential, on a paper-scale (flsim_small) CPU config.

    The same S-seed sweep runs two ways: S independent Executor runs (the
    pre-campaign cost of a multi-seed comparison) and one CampaignExecutor
    whose S trajectories share a single vmapped compiled program. Each
    executor gets a warm-up chunk first (compile excluded), so the speedup
    is steady-state throughput: dispatch amortization + batched lane math.
    By the campaign determinism contract the two produce bitwise-identical
    per-lane params, so the delta is pure execution efficiency. Writes
    ``out_path`` and prints one CSV row per mode.

    The default is the paper's scale-experiment model (logreg, Fig. 12):
    vmapping the trajectory axis pays where per-launch overhead dominates —
    at paper scale that is every model; a model whose per-lane working set
    overflows CPU cache (e.g. the 1M-param MLP at S=8) can instead go
    memory-bound, which is the documented trade-off, not a bug.
    """
    import json

    from repro.core.jobs import load_job
    from repro.runtime.campaign import CampaignExecutor
    from repro.runtime.executor import Executor

    assert rounds % chunk == 0, \
        "rounds must be a multiple of chunk (keeps the timed region free " \
        "of remainder-length compiles)"

    def raw(seed_s=seed, sweep=None):
        r = {
            "name": "bench-sweep",
            "model": {"arch": arch},
            "dataset": {"dataset": "synthetic_vision", "n_items": n_items,
                        "distribution": {"partition": "dirichlet",
                                         "dirichlet_alpha": 0.5}},
            "strategy": {"strategy": "fedavg",
                         "train_params": {"n_clients": n_clients,
                                          "local_epochs": 1,
                                          "client_lr": 0.1,
                                          "rounds": rounds + chunk,
                                          "seed": seed_s,
                                          "rounds_per_launch": chunk}},
        }
        if sweep:
            r["sweep"] = sweep
        return r

    seeds = [seed + s for s in range(n_traj)]
    results = {"config": {"arch": arch, "n_traj": n_traj,
                          "n_clients": n_clients, "rounds": rounds,
                          "chunk": chunk, "n_items": n_items, "seed": seed,
                          "backend": jax.default_backend()},
               "runs": {}}

    # sequential: S independent single runs (warm-up chunk each, excluded)
    execs = [Executor(load_job(raw(seed_s=s))).scaffold() for s in seeds]
    for ex in execs:
        ex.run(rounds=chunk)
    t0 = time.time()
    for ex in execs:
        ex.run(rounds=chunk + rounds)
    dt_seq = time.time() - t0

    # vmapped: one campaign, S trajectories per launch
    camp = CampaignExecutor(
        load_job(raw(sweep={"seeds": seeds}))).scaffold()
    camp.run(rounds=chunk)
    t0 = time.time()
    camp.run(rounds=chunk + rounds)
    dt_vm = time.time() - t0

    traj_rounds = n_traj * rounds        # trajectory-rounds moved per mode
    for name, dt in (("sequential", dt_seq), ("vmapped", dt_vm)):
        results["runs"][name] = {
            "trajectories": n_traj, "rounds": rounds, "wall_s": dt,
            "traj_rounds_per_s": traj_rounds / dt,
            "s_per_traj_round": dt / traj_rounds}
    speedup = dt_seq / dt_vm
    results["speedup_vmapped_vs_sequential"] = speedup
    for name in ("sequential", "vmapped"):
        r = results["runs"][name]
        print(f"sweep_{name},{r['s_per_traj_round']*1e6:.0f},"
              f"traj_rounds_per_s={r['traj_rounds_per_s']:.2f};"
              f"speedup={speedup if name == 'vmapped' else 1.0:.2f}")
    if out_path:
        with open(out_path, "w") as f:
            json.dump(results, f, indent=2)
    return results


def bench_plan(arch: str = "flsim-logreg", strategies=("fedavg", "fedprox"),
               n_seeds: int = 8, n_clients: int = 4, rounds: int = 16,
               chunk: int = 4, n_items: int = 256, batch_size: int = 16,
               seed: int = 0, reps: int = 6,
               out_path: str = "BENCH_plan.json"):
    """Trajectory-rounds/sec for a heterogeneous strategy x seed campaign,
    bucketed-vmap (planner) vs sequential, on a paper-scale CPU config.

    The same grid runs two ways: one independent Executor per (strategy,
    seed) point — the pre-planner cost of a cross-strategy comparison — and
    one PlanExecutor that buckets the grid by program signature (one bucket
    per strategy here) and vmaps the seeds within each bucket. Each path
    gets a warm-up chunk first (compile excluded), so the speedup is
    steady-state throughput. By the planner determinism contract the two
    produce bitwise-identical per-lane params, so the delta is pure
    execution efficiency. Also reports the compile counts: the bucketed
    path compiles one program per signature, the sequential path one per
    point. Writes ``out_path`` and prints one CSV row per mode.

    Both paths use the same ``rounds_per_launch`` chunking, so the speedup
    isolates bucketing; the per-bucket lane count is what pays (S=8 seeds
    per strategy here, same scale as ``bench_sweep``) — two buckets also
    means two dispatches per chunk, so the bucketed ratio sits slightly
    under the single-bucket sweep ratio by construction. The two modes'
    timed regions *interleave* over ``reps`` repetitions and each reports
    its best — on small shared CPU runners the noise floor moves on the
    scale of one region, so back-to-back phases would charge one mode for
    the other's unlucky window.
    """
    import json

    from repro.core.jobs import load_job
    from repro.runtime.executor import Executor
    from repro.runtime.scheduler import PlanExecutor

    assert rounds % chunk == 0, \
        "rounds must be a multiple of chunk (keeps the timed region free " \
        "of remainder-length compiles)"

    def raw(strategy="fedavg", seed_s=seed, sweep=None):
        r = {
            "name": "bench-plan",
            "model": {"arch": arch},
            "dataset": {"dataset": "synthetic_vision", "n_items": n_items,
                        "distribution": {"partition": "dirichlet",
                                         "dirichlet_alpha": 0.5}},
            "strategy": {"strategy": strategy,
                         "train_params": {"n_clients": n_clients,
                                          "local_epochs": 1,
                                          "client_lr": 0.1,
                                          "batch_size": batch_size,
                                          "rounds": chunk + reps * rounds,
                                          "seed": seed_s,
                                          "rounds_per_launch": chunk}},
        }
        if sweep:
            r["sweep"] = sweep
        return r

    seeds = [seed + s for s in range(n_seeds)]
    grid = [(st, sd) for st in strategies for sd in seeds]
    results = {"config": {"arch": arch, "strategies": list(strategies),
                          "n_seeds": n_seeds, "n_clients": n_clients,
                          "rounds": rounds, "chunk": chunk, "reps": reps,
                          "n_items": n_items, "batch_size": batch_size,
                          "seed": seed,
                          "backend": jax.default_backend()},
               "runs": {}}

    # sequential: one Executor per grid point; bucketed: one PlanExecutor,
    # one vmapped launch per signature bucket. Warm-up chunk each
    # (compile excluded), then interleaved timed reps.
    execs = [Executor(load_job(raw(st, sd))).scaffold() for st, sd in grid]
    pe = PlanExecutor(load_job(raw(
        sweep={"strategy": list(strategies), "seeds": seeds}))).scaffold()
    for ex in execs:
        ex.run(rounds=chunk)
    pe.run(rounds=chunk)
    dt_seq = dt_plan = float("inf")
    for rep in range(reps):
        upto = chunk + (rep + 1) * rounds
        t0 = time.time()
        for ex in execs:
            ex.run(rounds=upto)
        dt_seq = min(dt_seq, time.time() - t0)
        t0 = time.time()
        pe.run(rounds=upto)
        dt_plan = min(dt_plan, time.time() - t0)
    seq_programs = sum(ex.compiled_programs() for ex in execs)

    traj_rounds = len(grid) * rounds
    for name, dt in (("sequential", dt_seq), ("bucketed", dt_plan)):
        results["runs"][name] = {
            "trajectories": len(grid), "rounds": rounds, "wall_s": dt,
            "traj_rounds_per_s": traj_rounds / dt,
            "s_per_traj_round": dt / traj_rounds}
    results["runs"]["sequential"]["compiled_programs"] = seq_programs
    results["runs"]["bucketed"]["compiled_programs"] = pe.compiled_programs()
    results["n_buckets"] = len(pe.plan.buckets)
    speedup = dt_seq / dt_plan
    results["speedup_bucketed_vs_sequential"] = speedup
    for name in ("sequential", "bucketed"):
        r = results["runs"][name]
        print(f"plan_{name},{r['s_per_traj_round']*1e6:.0f},"
              f"traj_rounds_per_s={r['traj_rounds_per_s']:.2f};"
              f"programs={r['compiled_programs']};"
              f"speedup={speedup if name == 'bucketed' else 1.0:.2f}")
    if out_path:
        with open(out_path, "w") as f:
            json.dump(results, f, indent=2)
    return results


def bench_shard(arch: str = "flsim-logreg", n_traj: int = 16,
                n_devices: int = 4, n_clients: int = 8, rounds: int = 16,
                chunk: int = 4, n_items: int = 512, seed: int = 0,
                reps: int = 4, out_path: str = "BENCH_shard.json"):
    """Trajectory-rounds/sec for a device-parallel campaign: the S=16 seed
    grid sharded over a ``n_devices``-lane mesh vs the same campaign's
    1-device vmap, on fake CPU devices
    (``XLA_FLAGS=--xla_force_host_platform_device_count=4``;
    ``benchmarks.run --only shard`` sets the flag itself when absent).

    Both paths run the *same* compiled vmap program over the same S lanes —
    the sharded one just places the leading sweep dim of every plane under a
    ``NamedSharding`` over ``lanes``, so each device advances S/n lanes with
    zero collectives. By the sharding determinism contract
    (tests/test_shard_sweep.py) the two produce bitwise-identical per-lane
    params, so the delta is pure device parallelism. The default grid is the
    paper's scale-experiment model (logreg, Fig. 12) under the **async**
    event scan: a long chain of small serial ops is exactly the program
    shape one CPU device cannot thread (no big batched gemms for intra-op
    parallelism to chew on), so concurrent per-device lane shards show the
    cleanest win — while a model whose stacked working set is memory-bound
    (the 1M-param MLP caveat bench_sweep documents) gains little on a
    bandwidth-starved 2-core runner, since fake devices share one memory
    bus. Timed regions interleave over ``reps`` repetitions and report each
    mode's best (same noisy-runner rationale as bench_plan). Writes
    ``out_path`` and prints one CSV row per mode.
    """
    import json

    from repro.core.jobs import load_job
    from repro.runtime.campaign import CampaignExecutor

    if jax.device_count() < n_devices:
        raise RuntimeError(
            f"bench_shard wants {n_devices} devices but only "
            f"{jax.device_count()} are visible; set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n_devices} "
            "before jax initializes (benchmarks.run --only shard does)")
    assert rounds % chunk == 0, \
        "rounds must be a multiple of chunk (keeps the timed region free " \
        "of remainder-length compiles)"

    raw = {
        "name": "bench-shard",
        "model": {"arch": arch},
        "dataset": {"dataset": "synthetic_vision", "n_items": n_items,
                    "distribution": {"partition": "dirichlet",
                                     "dirichlet_alpha": 0.5}},
        "strategy": {"strategy": "fedavg",
                     "train_params": {"n_clients": n_clients,
                                      "local_epochs": 1,
                                      "client_lr": 0.1,
                                      "mode": "async", "async_buffer": 8,
                                      "max_staleness": 8,
                                      "staleness_exponent": 0.5,
                                      "rounds": chunk + reps * rounds,
                                      "seed": seed,
                                      "rounds_per_launch": chunk}},
        "runtime": {"straggler_prob": 0.1, "duration_sigma": 0.25},
        "sweep": {"seeds": [seed + s for s in range(n_traj)]},
    }
    results = {"config": {"arch": arch, "n_traj": n_traj,
                          "n_devices": n_devices, "n_clients": n_clients,
                          "rounds": rounds, "chunk": chunk, "reps": reps,
                          "n_items": n_items, "seed": seed,
                          "backend": jax.default_backend(),
                          "device_count": jax.device_count()},
               "runs": {}}

    vm = CampaignExecutor(load_job(raw)).scaffold()
    sh = CampaignExecutor(load_job(raw), lane_devices=n_devices).scaffold()
    vm.run(rounds=chunk)                     # warm-up: compile + stage
    sh.run(rounds=chunk)
    dt_vm = dt_sh = float("inf")
    for rep in range(reps):
        upto = chunk + (rep + 1) * rounds
        t0 = time.time()
        vm.run(rounds=upto)
        dt_vm = min(dt_vm, time.time() - t0)
        t0 = time.time()
        sh.run(rounds=upto)
        dt_sh = min(dt_sh, time.time() - t0)

    traj_rounds = n_traj * rounds
    for name, dt in (("vmapped_1dev", dt_vm), ("sharded", dt_sh)):
        results["runs"][name] = {
            "trajectories": n_traj, "rounds": rounds, "wall_s": dt,
            "traj_rounds_per_s": traj_rounds / dt,
            "s_per_traj_round": dt / traj_rounds}
    speedup = dt_vm / dt_sh
    results["speedup_sharded_vs_vmapped"] = speedup
    for name in ("vmapped_1dev", "sharded"):
        r = results["runs"][name]
        print(f"shard_{name},{r['s_per_traj_round']*1e6:.0f},"
              f"traj_rounds_per_s={r['traj_rounds_per_s']:.2f};"
              f"speedup={speedup if name == 'sharded' else 1.0:.2f}")
    if out_path:
        with open(out_path, "w") as f:
            json.dump(results, f, indent=2)
    return results


def bench_agg(n_params: int = 1 << 20, n_clients: int = 16,
              qblock: int = 256, reps: int = 30, seed: int = 0,
              out_path: str = "BENCH_agg.json"):
    """Fused int8 aggregation vs dequant-first, at the memory-bound
    1M-param MLP scale the sweep bench flagged (bench_sweep's docstring
    caveat: at that size a round is HBM-traffic-, not compute-, dominated —
    exactly the regime where reading each client byte once matters).

    One server reduce over C client sends in the kernel's packed layout
    ((C, N) int8 + (C, N/qblock) f32 scales — what ``compression: int8``
    runs actually aggregate every round/flush):

    - ``fused``         — ``ops._quant_agg_fused``: the unrolled
      dequant+weighted-sum XLA compiles to one pass; the (C, N) f32
      dequant never exists in memory.
    - ``dequant_first`` — ``ops._quant_agg_dequant_first``: materializes
      the full f32 dequant behind an ``optimization_barrier`` (identity on
      values, so the two are asserted bitwise equal here) before the same
      accumulation — the naive path's 4x write + 4x read-back traffic.

    Timed regions interleave over ``reps`` and report best-of (same noisy
    shared-runner rationale as bench_plan). Writes ``out_path`` with
    ``speedup_fused_vs_dequant`` — the bench gate's BENCH_agg contract
    (>= 1.5x) reads it.
    """
    import json

    from repro.kernels import ops

    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    n = n_params + (-n_params) % qblock
    qd = jax.random.randint(ks[0], (n_clients, n), -127, 128, jnp.int8)
    sc = jax.random.uniform(ks[1], (n_clients, n // qblock), jnp.float32,
                            1e-4, 1e-2)
    w = jax.random.uniform(ks[2], (n_clients,), jnp.float32)
    w = w / w.sum()

    fused = jax.jit(ops._quant_agg_fused)
    dequant = jax.jit(ops._quant_agg_dequant_first)
    a = jax.block_until_ready(fused(qd, sc, w))        # warm-up + compile
    b = jax.block_until_ready(dequant(qd, sc, w))
    assert (np.asarray(a) == np.asarray(b)).all(), \
        "fused and dequant-first paths diverged (bitwise contract)"

    dt = {"fused": float("inf"), "dequant_first": float("inf")}
    for _ in range(reps):
        for name, fn in (("fused", fused), ("dequant_first", dequant)):
            t0 = time.time()
            jax.block_until_ready(fn(qd, sc, w))
            dt[name] = min(dt[name], time.time() - t0)

    int8_mb = qd.size * 1 / 2**20
    results = {"config": {"n_params": n_params, "n_clients": n_clients,
                          "qblock": qblock, "reps": reps, "seed": seed,
                          "backend": jax.default_backend(),
                          "kernel_impl": ops.backend()},
               "runs": {}, "bitwise_equal": True}
    for name in ("fused", "dequant_first"):
        results["runs"][name] = {
            "best_s": dt[name],
            "agg_per_s": 1.0 / dt[name],
            "int8_GiBps": int8_mb / 1024 / dt[name]}
    speedup = dt["dequant_first"] / dt["fused"]
    results["speedup_fused_vs_dequant"] = speedup
    for name in ("fused", "dequant_first"):
        r = results["runs"][name]
        print(f"agg_{name},{r['best_s']*1e6:.0f},"
              f"int8_GiBps={r['int8_GiBps']:.2f};"
              f"speedup={speedup if name == 'fused' else 1.0:.2f}")
    if out_path:
        with open(out_path, "w") as f:
            json.dump(results, f, indent=2)
    return results


def bench_telemetry(arch: str = "flsim-logreg", n_traj: int = 8,
                    n_clients: int = 8, rounds: int = 16, chunk: int = 1,
                    n_items: int = 512, seed: int = 0, reps: int = 4,
                    artifact_dir: str = "telemetry_smoke",
                    out_path: str = "BENCH_telemetry.json"):
    """Flight-recorder overhead on the S=8 seed sweep grid (bench_sweep's
    vmapped campaign shape) at chunk=1 — the recorder's worst case: every
    round is a chunk boundary, so the span/counter plumbing fires at its
    maximum rate relative to useful work.

    The same campaign runs twice — telemetry off (no ``telemetry:``
    section: the no-op recorder) and on (streaming ``telemetry.jsonl`` to
    ``artifact_dir``) — with a warm-up chunk each (compile excluded) and
    timed regions interleaved over ``reps`` repetitions, reporting each
    mode's best (noisy-runner rationale as bench_plan/bench_shard). The
    recorder is host-side only, so the two runs share compiled programs
    bitwise; the gate (benchmarks/report.py: ``speedup_on_vs_off >= 0.95``)
    is the ISSUE's <=5% overhead budget. Also exports ``artifact_dir``'s
    Chrome trace + prints the breakdown report, so the bench doubles as
    the telemetry smoke artifact for CI upload. Writes ``out_path``."""
    import json

    from repro.core.jobs import load_job
    from repro.runtime.campaign import CampaignExecutor
    from repro.telemetry import trace as trace_mod

    assert rounds % chunk == 0, \
        "rounds must be a multiple of chunk (keeps the timed region free " \
        "of remainder-length compiles)"

    def raw(telemetry=False):
        r = {
            "name": "bench-telemetry",
            "model": {"arch": arch},
            "dataset": {"dataset": "synthetic_vision", "n_items": n_items,
                        "distribution": {"partition": "dirichlet",
                                         "dirichlet_alpha": 0.5}},
            "strategy": {"strategy": "fedavg",
                         "train_params": {"n_clients": n_clients,
                                          "local_epochs": 1,
                                          "client_lr": 0.1,
                                          "rounds": chunk + reps * rounds,
                                          "seed": seed,
                                          "rounds_per_launch": chunk}},
            "sweep": {"seeds": [seed + s for s in range(n_traj)]},
        }
        if telemetry:
            r["telemetry"] = {"out_dir": artifact_dir}
        return r

    results = {"config": {"arch": arch, "n_traj": n_traj,
                          "n_clients": n_clients, "rounds": rounds,
                          "chunk": chunk, "reps": reps, "n_items": n_items,
                          "seed": seed, "backend": jax.default_backend()},
               "runs": {}}

    off = CampaignExecutor(load_job(raw())).scaffold()
    on = CampaignExecutor(load_job(raw(telemetry=True))).scaffold()
    off.run(rounds=chunk)                    # warm-up: compile + stage
    on.run(rounds=chunk)
    dt_off = dt_on = float("inf")
    for rep in range(reps):
        upto = chunk + (rep + 1) * rounds
        t0 = time.time()
        off.run(rounds=upto)
        dt_off = min(dt_off, time.time() - t0)
        t0 = time.time()
        on.run(rounds=upto)
        dt_on = min(dt_on, time.time() - t0)
    on.recorder.close()

    traj_rounds = n_traj * rounds
    for name, dt in (("telemetry_off", dt_off), ("telemetry_on", dt_on)):
        results["runs"][name] = {
            "trajectories": n_traj, "rounds": rounds, "wall_s": dt,
            "traj_rounds_per_s": traj_rounds / dt,
            "s_per_traj_round": dt / traj_rounds}
    speedup = dt_off / dt_on
    results["speedup_on_vs_off"] = speedup
    results["events"] = len(on.recorder.events)
    for name in ("telemetry_off", "telemetry_on"):
        r = results["runs"][name]
        print(f"telemetry_{name},{r['s_per_traj_round']*1e6:.0f},"
              f"traj_rounds_per_s={r['traj_rounds_per_s']:.2f};"
              f"speedup={speedup if name == 'telemetry_on' else 1.0:.2f}")
    if artifact_dir:
        trace_path = trace_mod.export(artifact_dir)
        print(f"trace: {trace_path}")
        print(trace_mod.report(artifact_dir))
    if out_path:
        with open(out_path, "w") as f:
            json.dump(results, f, indent=2)
    return results


def bench_probes(arch: str = "flsim-logreg", n_traj: int = 8,
                 n_clients: int = 8, rounds: int = 16, chunk: int = 1,
                 local_epochs: int = 4, n_items: int = 1024, seed: int = 0,
                 reps: int = 4, artifact_dir: str = "probes_smoke",
                 out_path: str = "BENCH_probes.json"):
    """Round-probe overhead on the S=8 seed sweep grid at chunk=1 — the
    probe plane's worst case: probes ride the scan as extra outputs, and
    every round is a chunk boundary, so the drain (counter back-dating +
    probes.csv flush) fires at its maximum rate relative to useful work.

    ``local_epochs=4`` keeps the per-round *useful* work representative: a
    federated round canonically runs several local epochs per client
    (FedAvg's E), and the probe reductions are a fixed per-round cost —
    one extra pass over the already-materialized deltas regardless of how
    much training produced them. Benching against a one-batch round would
    measure the probes against a round that does almost nothing, which is
    the one configuration no real campaign uses.

    The same campaign runs twice — probes+telemetry off and on (probes are
    an observability feature: the realistic "on" cost includes the flight
    recorder that receives them) — with a warm-up chunk each (compile
    excluded) and timed regions interleaved over ``reps`` repetitions,
    reporting each mode's best. The two runs are bitwise-identical in
    params by the probe plane's contract; the gate (benchmarks/report.py:
    ``speedup_on_vs_off >= 0.9``) is the ISSUE's <=10% overhead budget.
    Also exports ``artifact_dir``'s Chrome trace (per-lane probe counter
    tracks) + probes.csv, the CI smoke artifacts. Writes ``out_path``."""
    import json

    from repro.core.jobs import load_job
    from repro.runtime.campaign import CampaignExecutor
    from repro.telemetry import trace as trace_mod

    assert rounds % chunk == 0, \
        "rounds must be a multiple of chunk (keeps the timed region free " \
        "of remainder-length compiles)"

    def raw(probes=False):
        r = {
            "name": "bench-probes",
            "model": {"arch": arch},
            "dataset": {"dataset": "synthetic_vision", "n_items": n_items,
                        "distribution": {"partition": "dirichlet",
                                         "dirichlet_alpha": 0.5}},
            "strategy": {"strategy": "fedavg",
                         "train_params": {"n_clients": n_clients,
                                          "local_epochs": local_epochs,
                                          "client_lr": 0.1,
                                          "rounds": chunk + reps * rounds,
                                          "seed": seed,
                                          "rounds_per_launch": chunk}},
            "sweep": {"seeds": [seed + s for s in range(n_traj)]},
        }
        if probes:
            r["probes"] = {"enabled": True, "out_dir": artifact_dir}
            r["telemetry"] = {"out_dir": artifact_dir}
        return r

    results = {"config": {"arch": arch, "n_traj": n_traj,
                          "n_clients": n_clients, "rounds": rounds,
                          "chunk": chunk, "reps": reps, "n_items": n_items,
                          "seed": seed, "backend": jax.default_backend()},
               "runs": {}}

    off = CampaignExecutor(load_job(raw())).scaffold()
    on = CampaignExecutor(load_job(raw(probes=True))).scaffold()
    off.run(rounds=chunk)                    # warm-up: compile + stage
    on.run(rounds=chunk)
    dt_off = dt_on = float("inf")
    for rep in range(reps):
        upto = chunk + (rep + 1) * rounds
        t0 = time.time()
        off.run(rounds=upto)
        dt_off = min(dt_off, time.time() - t0)
        t0 = time.time()
        on.run(rounds=upto)
        dt_on = min(dt_on, time.time() - t0)
    on.recorder.close()

    traj_rounds = n_traj * rounds
    for name, dt in (("probes_off", dt_off), ("probes_on", dt_on)):
        results["runs"][name] = {
            "trajectories": n_traj, "rounds": rounds, "wall_s": dt,
            "traj_rounds_per_s": traj_rounds / dt,
            "s_per_traj_round": dt / traj_rounds}
    speedup = dt_off / dt_on
    results["speedup_on_vs_off"] = speedup
    results["probe_rows"] = len(on.probe_rows)
    for name in ("probes_off", "probes_on"):
        r = results["runs"][name]
        print(f"probes_{name},{r['s_per_traj_round']*1e6:.0f},"
              f"traj_rounds_per_s={r['traj_rounds_per_s']:.2f};"
              f"speedup={speedup if name == 'probes_on' else 1.0:.2f}")
    if artifact_dir:
        trace_path = trace_mod.export(artifact_dir)
        print(f"trace: {trace_path}")
        print(trace_mod.report(artifact_dir))
    if out_path:
        with open(out_path, "w") as f:
            json.dump(results, f, indent=2)
    return results


def bench_comms(arch: str = "flsim-logreg", n_traj: int = 8,
                n_clients: int = 8, rounds: int = 16, chunk: int = 1,
                local_epochs: int = 4, n_items: int = 1024, seed: int = 0,
                reps: int = 4, artifact_dir: str = "comms_smoke",
                out_path: str = "BENCH_comms.json"):
    """Comms-observatory overhead on the S=8 seed sweep grid at chunk=1 —
    the accounting plane's worst case: every round is a chunk boundary, so
    the per-lane host accountants, the counter drain, and the comms.csv
    flush all fire at their maximum rate relative to useful work
    (``local_epochs=4`` keeps the per-round useful work representative,
    same rationale as ``bench_probes``).

    The same campaign runs twice — comms+telemetry off and on (comms is an
    observability feature: the realistic "on" cost includes the flight
    recorder its counters stream into) — with a warm-up chunk each
    (compile excluded) and timed regions interleaved over ``reps``
    repetitions, reporting each mode's best. The two runs are bitwise
    identical in params by the comms plane's zero-device-code contract;
    the gate (benchmarks/report.py: ``speedup_on_vs_off >= 0.95``) is the
    ISSUE's <=5% host-accounting budget. Also exports ``artifact_dir``'s
    Chrome trace (per-lane ``comms:*`` counter tracks) + comms.csv, the CI
    smoke artifacts. Writes ``out_path``."""
    import json

    from repro.core.jobs import load_job
    from repro.runtime.campaign import CampaignExecutor
    from repro.telemetry import trace as trace_mod

    assert rounds % chunk == 0, \
        "rounds must be a multiple of chunk (keeps the timed region free " \
        "of remainder-length compiles)"

    def raw(comms=False):
        r = {
            "name": "bench-comms",
            "model": {"arch": arch},
            "dataset": {"dataset": "synthetic_vision", "n_items": n_items,
                        "distribution": {"partition": "dirichlet",
                                         "dirichlet_alpha": 0.5}},
            "strategy": {"strategy": "fedavg",
                         "train_params": {"n_clients": n_clients,
                                          "local_epochs": local_epochs,
                                          "client_lr": 0.1,
                                          "rounds": chunk + reps * rounds,
                                          "seed": seed,
                                          "rounds_per_launch": chunk}},
            "sweep": {"seeds": [seed + s for s in range(n_traj)]},
        }
        if comms:
            r["comms"] = {"enabled": True, "out_dir": artifact_dir}
            r["telemetry"] = {"out_dir": artifact_dir}
        return r

    results = {"config": {"arch": arch, "n_traj": n_traj,
                          "n_clients": n_clients, "rounds": rounds,
                          "chunk": chunk, "reps": reps, "n_items": n_items,
                          "seed": seed, "backend": jax.default_backend()},
               "runs": {}}

    off = CampaignExecutor(load_job(raw())).scaffold()
    on = CampaignExecutor(load_job(raw(comms=True))).scaffold()
    off.run(rounds=chunk)                    # warm-up: compile + stage
    on.run(rounds=chunk)
    dt_off = dt_on = float("inf")
    for rep in range(reps):
        upto = chunk + (rep + 1) * rounds
        t0 = time.time()
        off.run(rounds=upto)
        dt_off = min(dt_off, time.time() - t0)
        t0 = time.time()
        on.run(rounds=upto)
        dt_on = min(dt_on, time.time() - t0)
    on.recorder.close()

    traj_rounds = n_traj * rounds
    for name, dt in (("comms_off", dt_off), ("comms_on", dt_on)):
        results["runs"][name] = {
            "trajectories": n_traj, "rounds": rounds, "wall_s": dt,
            "traj_rounds_per_s": traj_rounds / dt,
            "s_per_traj_round": dt / traj_rounds}
    speedup = dt_off / dt_on
    results["speedup_on_vs_off"] = speedup
    results["comms_rows"] = len(on.comms_rows)
    for name in ("comms_off", "comms_on"):
        r = results["runs"][name]
        print(f"comms_{name},{r['s_per_traj_round']*1e6:.0f},"
              f"traj_rounds_per_s={r['traj_rounds_per_s']:.2f};"
              f"speedup={speedup if name == 'comms_on' else 1.0:.2f}")
    if artifact_dir:
        trace_path = trace_mod.export(artifact_dir)
        print(f"trace: {trace_path}")
        print(trace_mod.report(artifact_dir))
    if out_path:
        with open(out_path, "w") as f:
            json.dump(results, f, indent=2)
    return results


def bench_stream(arch: str = "flsim-logreg", n_clients: int = 256,
                 cohort: int = 16, max_cohort: int = 20, rounds: int = 16,
                 chunk: int = 4, reps: int = 3, n_items: int = 2048,
                 local_epochs: int = 2, seed: int = 0,
                 population: int = 100_000, pop_rounds: int = 4,
                 out_path: str = "BENCH_stream.json"):
    """The streaming client plane: (a) double-buffered per-chunk staging
    vs the resident device gather on a config that fits in memory — same
    compiled program, same bytes, so the runs are bitwise identical and
    the only question is throughput (gated >= 0.9x in
    benchmarks/report.py: the prefetch thread must hide the host
    assembly); (b) a synthetic population too large to stage resident
    (``population`` clients) training through the sync driver, reporting
    the peak staged working set against the resident-equivalent bytes off
    the ``staged_bytes`` telemetry counters. Writes ``out_path``."""
    import json
    import tempfile

    from repro.core.jobs import load_job
    from repro.runtime.executor import Executor
    from repro.telemetry.recorder import read_events

    assert rounds % chunk == 0, \
        "rounds must be a multiple of chunk (keeps the timed region free " \
        "of remainder-length compiles)"

    def raw(streaming):
        return {
            "name": "bench-stream",
            "model": {"arch": arch},
            "dataset": {"dataset": "synthetic_vision", "n_items": n_items,
                        "distribution": {"partition": "dirichlet",
                                         "dirichlet_alpha": 0.5}},
            "strategy": {"strategy": "fedavg",
                         "train_params": {"n_clients": n_clients,
                                          "cohort": cohort,
                                          "max_cohort": max_cohort,
                                          "streaming": streaming,
                                          "local_epochs": local_epochs,
                                          "client_lr": 0.1,
                                          "rounds": chunk + reps * rounds,
                                          "seed": seed,
                                          "rounds_per_launch": chunk}},
            "runtime": {"straggler_prob": 0.1,
                        "straggler_overprovision": 1.25},
        }

    results = {"config": {"arch": arch, "n_clients": n_clients,
                          "cohort": cohort, "max_cohort": max_cohort,
                          "rounds": rounds, "chunk": chunk, "reps": reps,
                          "n_items": n_items, "population": population,
                          "backend": jax.default_backend()},
               "runs": {}}

    res = Executor(load_job(raw(False))).scaffold()
    stm = Executor(load_job(raw(True))).scaffold()
    res.run(rounds=chunk)                    # warm-up: compile + stage
    stm.run(rounds=chunk)
    dt_res = dt_stm = float("inf")
    for rep in range(reps):
        upto = chunk + (rep + 1) * rounds
        t0 = time.time()
        res.run(rounds=upto)
        dt_res = min(dt_res, time.time() - t0)
        t0 = time.time()
        stm.run(rounds=upto)
        dt_stm = min(dt_stm, time.time() - t0)
    for a, b in zip(jax.tree.leaves(res.state["params"]),
                    jax.tree.leaves(stm.state["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for name, dt in (("resident", dt_res), ("streaming", dt_stm)):
        results["runs"][name] = {
            "rounds": rounds, "wall_s": dt, "rounds_per_s": rounds / dt,
            "s_per_round": dt / rounds}
    speedup = dt_res / dt_stm
    results["speedup_streaming_vs_resident"] = speedup
    for name in ("resident", "streaming"):
        r = results["runs"][name]
        print(f"stream_{name},{r['s_per_round']*1e6:.0f},"
              f"rounds_per_s={r['rounds_per_s']:.2f};"
              f"speedup={speedup if name == 'streaming' else 1.0:.2f}")

    # (b) the population that cannot be staged resident
    tdir = tempfile.mkdtemp(prefix="bench-stream-")
    pop_job = load_job({
        "name": "bench-stream-pop",
        "model": {"arch": arch},
        "dataset": {"dataset": "synthetic_population",
                    "n_items": population, "items_per_client": 8},
        "strategy": {"strategy": "fedavg",
                     "train_params": {"n_clients": population,
                                      "cohort": cohort,
                                      "max_cohort": max_cohort,
                                      "streaming": True,
                                      "client_lr": 0.1,
                                      "rounds": chunk + pop_rounds,
                                      "seed": seed,
                                      "rounds_per_launch": chunk}},
        "runtime": {"straggler_prob": 0.1,
                    "straggler_overprovision": 1.25},
        "telemetry": {"enabled": True, "out_dir": tdir},
    })
    ex = Executor(pop_job).scaffold()
    ex.run(rounds=chunk)                     # warm-up chunk
    t0 = time.time()
    ex.run(rounds=chunk + pop_rounds)
    dt_pop = time.time() - t0
    ex.recorder.close()
    slabs = [e["values"] for e in read_events(tdir)
             if e.get("kind") == "counter"
             and e.get("name") == "staged_bytes"
             and "slab" in e.get("values", {})]
    peak = max(v["peak_slab"] for v in slabs)
    resident_equiv = max(v["resident_equiv"] for v in slabs)
    results["population_run"] = {
        "n_clients": population, "rounds": pop_rounds, "wall_s": dt_pop,
        "rounds_per_s": pop_rounds / dt_pop,
        "peak_slab_bytes": peak, "resident_equiv_bytes": resident_equiv,
        "working_set_ratio": peak / resident_equiv}
    print(f"stream_population,{dt_pop/pop_rounds*1e6:.0f},"
          f"clients={population};peak_slab_MiB={peak/2**20:.1f};"
          f"resident_equiv_MiB={resident_equiv/2**20:.1f};"
          f"working_set_ratio={peak/resident_equiv:.6f}")
    if out_path:
        with open(out_path, "w") as f:
            json.dump(results, f, indent=2)
    return results


def run_fl(fl: FLConfig, arch: str = "flsim-cnn", n_items: int = 768,
           rounds: int = 8, batch: int = 16, steps: int = 1,
           eval_n: int = 256, arch_cfg=None, run_name: str = "run"):
    cfg = arch_cfg or get_config(arch)
    if cfg.name == "flsim-cnn":
        cfg = cfg.replace(d_model=32, d_ff=64)      # CPU-scale channels
    model = model_zoo.build(cfg)
    strategy = get_strategy(fl)
    decentralized = fl.topology == "decentralized"
    round_fn = jax.jit(lambda s, b, w, r: build_spatial_round(
        model, strategy, fl)(AxisCtx(), s, b, w, r))

    from repro.models.small import input_shape
    data = SyntheticVision(n_items=n_items, shape=input_shape(cfg),
                           seed=fl.seed)
    x, y, parts = data.distribute_into_chunks(fl.partition, fl.n_clients,
                                              fl.dirichlet_alpha)
    state = init_state(model, strategy, fl, determinism.root_key(fl.seed),
                       n_clients_local=fl.n_clients,
                       decentralized=decentralized)
    logger = PerformanceLogger(run_name=run_name)
    test = {"x": jnp.asarray(x[:eval_n]), "y": jnp.asarray(y[:eval_n])}
    root = determinism.root_key(fl.seed)
    comm = comm_bytes_per_round(state["params"], fl)
    batch = min(batch, max(min(len(p) for p in parts), 1))  # uniform shapes
    for r in range(rounds):
        bs = [SyntheticVision.client_batches(
            x, y, parts[c], batch, steps,
            seed=fl.seed * 7919 + c + r * 104729)[0]
            for c in range(fl.n_clients)]
        b = jax.tree.map(lambda *t: np.stack(t), *bs)
        w = jnp.asarray([len(p) for p in parts], jnp.float32)
        t0 = time.time()
        state, m = round_fn(state, b, w, determinism.round_key(root, r))
        dt = time.time() - t0
        params_eval = state["params"]
        if decentralized:
            params_eval = jax.tree.map(lambda t: t.mean(0), params_eval)
        acc = float(model.accuracy(params_eval, test))
        logger.log_round(r, loss=float(m["loss"]), accuracy=acc,
                         round_s=dt, comm_mb=comm / 2**20)
    return state, logger
