"""Render EXPERIMENTS.md tables from results/{dryrun,roofline}/*.json, and
gate the BENCH_*.json speedup contracts (``python -m benchmarks.report
bench``): collate every artifact into a markdown table — appended to
``$GITHUB_STEP_SUMMARY`` when set — and exit nonzero when any measured
speedup falls below its contract floor, so a perf regression fails CI
instead of silently shipping in an artifact nobody reads."""
import json
import os
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]

# The speedup contracts CI enforces: artifact stem -> (floor, what the
# number claims, how to read it out of the JSON). Floors mirror the ROADMAP
# execution-model contracts (each was set one PR earlier than the gate, so
# every floor has headroom on the reference 2-core runner).
BENCH_CONTRACTS = {
    "BENCH_driver": (1.5, "chunk=10 vs chunk=1 launches",
                     lambda r: max(run["speedup_vs_chunk1"]
                                   for run in r["runs"].values())),
    "BENCH_async": (1.5, "chunked event scan vs per-event launches",
                    lambda r: max(run["speedup_vs_per_event"]
                                  for run in r["runs"].values())),
    "BENCH_sweep": (2.0, "S=8 vmapped campaign vs sequential runs",
                    lambda r: r["speedup_vmapped_vs_sequential"]),
    "BENCH_plan": (2.0, "bucketed heterogeneous grid vs sequential runs",
                   lambda r: r["speedup_bucketed_vs_sequential"]),
    "BENCH_shard": (1.5, "4-device lane-sharded campaign vs 1-device vmap",
                    lambda r: r["speedup_sharded_vs_vmapped"]),
    "BENCH_agg": (1.5, "fused int8 aggregation vs dequant-first",
                  lambda r: r["speedup_fused_vs_dequant"]),
    # overhead budgets, not speedup claims: 0.95x = the flight recorder
    # may cost at most 5% on the chunk=1 worst case; 0.9x = probes (which
    # ride the scan *and* feed the recorder) at most 10%
    "BENCH_telemetry": (0.95,
                        "campaign with flight recorder vs telemetry off",
                        lambda r: r["speedup_on_vs_off"]),
    "BENCH_probes": (0.9,
                     "campaign with round probes + recorder vs both off",
                     lambda r: r["speedup_on_vs_off"]),
    # 0.95x = the comms observatory (pure host accounting + recorder)
    # may cost at most 5% on the chunk=1 worst case
    "BENCH_comms": (0.95,
                    "campaign with comms accounting + recorder vs both off",
                    lambda r: r["speedup_on_vs_off"]),
    # 0.9x = double-buffered streaming staging may cost at most 10% vs the
    # resident device gather (same compiled program, same bytes — the
    # prefetch thread must hide the host assembly)
    "BENCH_stream": (0.9,
                     "streaming slab staging vs resident device gather",
                     lambda r: r["speedup_streaming_vs_resident"]),
}


def bench_records(bench_dir=".", only=None) -> list:
    """Score each contract into a record dict: the single source both the
    markdown table and ``--json`` render. ``margin`` is measured/floor — 1
    (how much headroom is left; negative = below floor)."""
    if only is not None:
        unknown = [o for o in only
                   if f"BENCH_{o}" not in BENCH_CONTRACTS]
        if unknown:
            raise KeyError(f"unknown bench contract(s) {unknown}; known: "
                           f"{[s[6:] for s in BENCH_CONTRACTS]}")
    records = []
    for stem, (floor, claim, read) in BENCH_CONTRACTS.items():
        if only is not None and stem[6:] not in only:
            continue
        rec = {"artifact": stem, "claim": claim, "floor": floor,
               "measured": None, "margin": None}
        path = pathlib.Path(bench_dir) / f"{stem}.json"
        if not path.exists():
            # a gate invoked with --only asserts its job just measured
            # these — a missing artifact there is a violation (a bench
            # that exited 0 without writing must not green-light CI),
            # while the bare gate merely reports coverage
            rec["status"] = ("fail (not measured)" if only is not None
                             else "skipped (no artifact)")
        else:
            try:
                rec["measured"] = float(read(json.loads(path.read_text())))
                rec["margin"] = rec["measured"] / floor - 1.0
                rec["status"] = ("pass" if rec["measured"] >= floor
                                 else "fail")
            except (KeyError, ValueError, TypeError) as e:
                rec["status"] = f"fail (unreadable: {e!r})"
        records.append(rec)
    return records


def bench_gate(bench_dir=".", only=None, as_json=False) -> int:
    """Collate BENCH_*.json into a markdown table and enforce the floors.

    Returns the number of violations (the CLI exits 1 if any). ``only`` names
    the contracts to enforce (e.g. ``["driver", "shard"]``; None = all):
    each CI job gates exactly the artifacts it just measured — the repo
    also *commits* BENCH_*.json as the recorded perf trajectory, so after
    checkout every artifact exists and a gate without ``only`` would score
    stale committed numbers a job never reproduced. Artifacts absent from
    ``bench_dir`` are reported as skipped, not failed. ``as_json`` prints
    the records as JSON on stdout instead of the table (the step-summary
    markdown still renders either way)."""
    records = bench_records(bench_dir, only=only)
    bad = sum(1 for r in records if r["status"].startswith("fail"))
    rows = []
    for r in records:
        measured = (f"{r['measured']:.2f}x" if r["measured"] is not None
                    else "—")
        margin = (f"{100 * r['margin']:+.0f}%" if r["margin"] is not None
                  else "—")
        status = ("**FAIL**" + r["status"][4:]
                  if r["status"].startswith("fail") else r["status"])
        rows.append(f"| {r['artifact']} | {r['claim']} | {measured} | "
                    f"≥{r['floor']:.2f}x | {margin} | {status} |")
    table = "\n".join(
        ["## Benchmark speedup contracts\n",
         "| artifact | claim | measured | floor | margin | status |",
         "|---|---|---|---|---|---|", *rows])
    print(json.dumps({"violations": bad, "contracts": records}, indent=2)
          if as_json else table)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(table + "\n")
    if bad:
        # floors are the ROADMAP contract values; a miss usually means a
        # real regression, but shared-runner noise can clip the thinner
        # recorded margins (plan: 2.16x vs 2.0x floor, shard: 1.64x vs
        # 1.5x on a 2-core box), so re-run the job once before hunting a
        # culprit commit
        print(f"\nbench gate: {bad} contract(s) below floor "
              "(re-run the job once if shared-runner noise is plausible)",
              file=sys.stderr)
    return bad


def dryrun_table() -> str:
    rows = []
    for f in sorted((ROOT / "results" / "dryrun").glob("*.json")):
        if "__L" in f.stem or f.stem.count("__") > 2:
            continue
        r = json.loads(f.read_text())
        rows.append(r)
    out = ["| arch | shape | mesh | compile_s | args GiB | temp GiB | "
           "collectives (counts) |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        cc = ", ".join(f"{k.split('-')[0]}-{k.split('-')[1][0]}:{v}"
                       if "-" in k else f"{k}:{v}"
                       for k, v in sorted(
                           r["collectives"]["counts"].items()))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compile_s']} | {r['memory']['args_GiB']:.2f} | "
            f"{r['memory']['temp_GiB']:.2f} | {cc} |")
    return "\n".join(out)


def roofline_table(tag=None) -> str:
    rows = []
    for f in sorted((ROOT / "results" / "roofline").glob("*.json")):
        parts = f.stem.split("__")
        ftag = parts[2] if len(parts) > 2 else None
        if ftag != tag:
            continue
        rows.append(json.loads(f.read_text()))
    out = ["| arch | shape | compute_s | memory_s (kernelized) | "
           "memory_s (raw) | collective_s | dominant | useful | roofline | "
           "peak GiB | fits 16G |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r.get('memory_raw_s', r['memory_s']):.3f} | "
            f"{r['collective_s']:.3f} | {r['dominant'].replace('_s','')} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} | "
            f"{r['memory_peak_GiB']:.1f} | "
            f"{'yes' if r.get('fits_hbm16') else 'NO'} |")
    return "\n".join(out)


if __name__ == "__main__":
    # bench gate: python -m benchmarks.report bench [--only a,b,...] [dir]
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which == "bench":
        only, bench_dir, as_json = None, ".", False
        usage = ("usage: benchmarks.report bench [--only a,b,...] "
                 "[--json] [dir]")
        rest = sys.argv[2:]
        while rest:
            tok = rest.pop(0)
            if tok == "--only":
                if not rest:
                    sys.exit(usage)
                only = rest.pop(0).split(",")
            elif tok == "--json":
                as_json = True
            elif tok.startswith("-"):
                # a typo'd flag must not silently become bench_dir and
                # un-scope the gate
                sys.exit(f"unknown option {tok!r}; {usage}")
            else:
                bench_dir = tok
        sys.exit(1 if bench_gate(bench_dir, only=only, as_json=as_json)
                 else 0)
    if which in ("all", "dryrun"):
        print("## Dry-run\n")
        print(dryrun_table())
    if which in ("all", "roofline"):
        print("\n## Roofline (single-pod 16x16)\n")
        print(roofline_table())
    if which in ("all", "opt"):
        print("\n## Optimized cells\n")
        print(roofline_table(tag="final_opt"))
