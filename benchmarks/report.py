"""Render EXPERIMENTS.md tables from results/{dryrun,roofline}/*.json."""
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]


def dryrun_table() -> str:
    rows = []
    for f in sorted((ROOT / "results" / "dryrun").glob("*.json")):
        if "__L" in f.stem or f.stem.count("__") > 2:
            continue
        r = json.loads(f.read_text())
        rows.append(r)
    out = ["| arch | shape | mesh | compile_s | args GiB | temp GiB | "
           "collectives (counts) |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        cc = ", ".join(f"{k.split('-')[0]}-{k.split('-')[1][0]}:{v}"
                       if "-" in k else f"{k}:{v}"
                       for k, v in sorted(
                           r["collectives"]["counts"].items()))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compile_s']} | {r['memory']['args_GiB']:.2f} | "
            f"{r['memory']['temp_GiB']:.2f} | {cc} |")
    return "\n".join(out)


def roofline_table(tag=None) -> str:
    rows = []
    for f in sorted((ROOT / "results" / "roofline").glob("*.json")):
        parts = f.stem.split("__")
        ftag = parts[2] if len(parts) > 2 else None
        if ftag != tag:
            continue
        rows.append(json.loads(f.read_text()))
    out = ["| arch | shape | compute_s | memory_s (kernelized) | "
           "memory_s (raw) | collective_s | dominant | useful | roofline | "
           "peak GiB | fits 16G |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r.get('memory_raw_s', r['memory_s']):.3f} | "
            f"{r['collective_s']:.3f} | {r['dominant'].replace('_s','')} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} | "
            f"{r['memory_peak_GiB']:.1f} | "
            f"{'yes' if r.get('fits_hbm16') else 'NO'} |")
    return "\n".join(out)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("## Dry-run\n")
        print(dryrun_table())
    if which in ("all", "roofline"):
        print("\n## Roofline (single-pod 16x16)\n")
        print(roofline_table())
    if which in ("all", "opt"):
        print("\n## Optimized cells\n")
        print(roofline_table(tag="final_opt"))
