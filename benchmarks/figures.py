"""Paper-figure replications (one function per paper table/figure).

Each returns rows of (name, metric dict) and prints CSV. Scaled to CPU
minutes; relative orderings are the claim being reproduced.
"""
from __future__ import annotations

import time

import numpy as np

from repro.configs.base import FLConfig, get_config
from benchmarks.flbench import run_fl


def _fmt(name, logger, extra=""):
    accs = logger.series("accuracy")
    losses = logger.series("loss")
    t = sum(logger.series("round_s"))
    comm = logger.rows[-1]["comm_mb"]
    row = (f"{name},{t*1e6/max(len(accs),1):.0f},"
           f"acc={accs[-1]:.3f};loss={losses[-1]:.3f};"
           f"time_s={t:.1f};comm_mb={comm:.1f}{extra}")
    print(row, flush=True)
    return {"name": name, "acc": accs[-1], "loss": losses[-1], "time": t,
            "comm_mb": comm, "accs": accs, "losses": losses}


def fig8_frameworks(rounds=8, n_clients=10):
    """Paper Fig. 8: seven FL frameworks on one workload."""
    settings = {
        "fedavg": FLConfig(strategy="fedavg"),
        "fedavgm": FLConfig(strategy="fedavgm", server_momentum=0.9),
        # SCAFFOLD's variate correction scales as 1/(K*lr); at the CPU-scaled
        # lr=0.05 it is unstable (paper runs lr=1e-3) -> paper-faithful lr.
        "scaffold": FLConfig(strategy="scaffold", client_lr=0.01),
        "moon": FLConfig(strategy="moon", moon_mu=0.1),
        "dp_fedavg": FLConfig(strategy="dp_fedavg", dp_clip=5.0,
                              dp_noise=1e-3),
        "clustered_hier": FLConfig(strategy="clustered",
                                   topology="hierarchical"),
        "fedstellar_gossip": FLConfig(strategy="gossip",
                                      topology="decentralized",
                                      gossip_steps=2),
    }
    out = []
    for name, fl in settings.items():
        lr = fl.client_lr if fl.strategy == "scaffold" else 0.05
        fl = fl.__class__(**{**fl.__dict__, "n_clients": n_clients,
                             "local_epochs": 2, "client_lr": lr,
                             "partition": "dirichlet",
                             "dirichlet_alpha": 0.5, "seed": 0})
        _, logger = run_fl(fl, "flsim-cnn", rounds=rounds, run_name=name)
        out.append(_fmt(f"fig8_{name}", logger))
    return out


def fig9_agnosticism(rounds=8):
    """Paper Fig. 9 recast: model/pytree agnosticism — CNN vs MLP vs logreg
    under the identical FedAvg harness (RQ2)."""
    out = []
    for arch in ("flsim-cnn", "flsim-mlp", "flsim-logreg"):
        fl = FLConfig(strategy="fedavg", n_clients=10, local_epochs=2,
                      client_lr=0.05, dirichlet_alpha=0.5, seed=0)
        _, logger = run_fl(fl, arch, rounds=rounds, run_name=arch)
        out.append(_fmt(f"fig9_{arch}", logger))
    return out


def fig10_multiworker(rounds=6):
    """Paper Fig. 10: malicious workers vs consensus (1M-0H..1M-3H)."""
    out = []
    for n_workers, label in [(1, "1M-0H"), (2, "1M-1H"), (3, "1M-2H"),
                             (4, "1M-3H")]:
        fl = FLConfig(strategy="fedavg", n_clients=10, local_epochs=1,
                      client_lr=0.05, n_workers=n_workers,
                      byzantine_workers=1, consensus="majority_digest",
                      seed=0)
        _, logger = run_fl(fl, "flsim-mlp", rounds=rounds, run_name=label)
        out.append(_fmt(f"fig10_{label}", logger))
    return out


def fig11_topologies(rounds=8):
    """Paper Fig. 11: client-server vs hierarchical vs decentralized."""
    out = []
    for topo in ("client_server", "hierarchical", "decentralized"):
        fl = FLConfig(strategy="fedavg", topology=topo, n_clients=10,
                      local_epochs=2, client_lr=0.05, gossip_steps=2, seed=0)
        _, logger = run_fl(fl, "flsim-cnn", rounds=rounds, run_name=topo)
        out.append(_fmt(f"fig11_{topo}", logger))
    return out


def tab12_reproducibility(rounds=5, trials=3):
    """Paper Tables 1-2: per-trial accuracy/loss — bitwise equal trials."""
    out = []
    series = []
    for t in range(trials):
        fl = FLConfig(strategy="fedavg", n_clients=10, local_epochs=1,
                      client_lr=0.05, seed=11)
        _, logger = run_fl(fl, "flsim-mlp", rounds=rounds,
                           run_name=f"trial{t}")
        accs = tuple(logger.series("accuracy"))
        losses = tuple(logger.series("loss"))
        series.append((accs, losses))
        print(f"tab12_trial{t}," +
              ";".join(f"{a:.6f}" for a in accs), flush=True)
        out.append({"trial": t, "accs": accs, "losses": losses})
    identical = all(s == series[0] for s in series)
    print(f"tab12_identical,{int(identical)},bitwise={identical}")
    assert identical, "trials must be bitwise identical (RQ6)"
    return out


def campaign_curves(results, metric: str = "loss", seed_axis: str = "seed",
                    out_png: str = None):
    """Multi-seed mean±band curves from a campaign results table.

    ``results`` is either a list of tidy rows (``CampaignExecutor.results``)
    or a path to a ``campaign.csv``. Rows group by every sweep coordinate
    except ``seed_axis``; within each group the per-round mean and std over
    seeds form one curve + band. Prints one CSV row per group; if
    matplotlib is importable (it is optional) and ``out_png`` is set, also
    draws the banded curves.
    """
    # group strictly by sweep coordinates (the campaign schema's leading
    # columns are always sweep axis names), so metric/eval columns can
    # never fragment the grouping regardless of chunk size
    from repro.core.sweeps import KNOWN_AXES
    results = _load_rows(results)
    if not results:
        return []
    group_keys = [k for k in KNOWN_AXES
                  if k != seed_axis and k in results[0]]
    return _banded_curves(results, group_keys, metric, out_png,
                          prefix="campaign")


def strategy_comparison(results, metric: str = "loss", out_png: str = None):
    """Cross-strategy mean±band curves from a merged heterogeneous-campaign
    table (``PlanExecutor`` rows or its ``campaign.csv``).

    One curve per strategy: within each strategy the per-round mean and std
    pool every other axis (seeds, topologies, lrs ... — the planner's
    "compare algorithms under one job config" reading of the paper's
    cross-framework figures). Prints one CSV row per strategy; draws the
    banded curves when matplotlib is importable and ``out_png`` is set.
    """
    results = _load_rows(results)
    if not results:
        return []
    return _banded_curves(results, ["strategy"], metric, out_png,
                          prefix="strategy")


def time_to_accuracy(results, metric: str = "accuracy",
                     seed_axis: str = "seed", out_png: str = None):
    """Banded metric-vs-simulated-wall-clock curves (comms observatory).

    Needs a comms-accounted campaign table: the ``sim_time_s`` column the
    executor joins onto the result rows becomes the x-axis, grouped like
    ``campaign_curves`` (every sweep coordinate except ``seed_axis``). Rows
    missing either column (comms off, non-eval rounds for eval metrics)
    are skipped."""
    return _axis_curves(results, metric, seed_axis, out_png,
                        x_key="sim_time_s", prefix="time_to_acc")


def bytes_to_accuracy(results, metric: str = "accuracy",
                      seed_axis: str = "seed", out_png: str = None):
    """Banded metric-vs-cumulative-wire-bytes curves (comms observatory):
    the ``cum_bytes`` column as x-axis — the figure that shows int8/topk
    lanes reaching a given accuracy on a fraction of the dense traffic."""
    return _axis_curves(results, metric, seed_axis, out_png,
                        x_key="cum_bytes", prefix="bytes_to_acc")


def _axis_curves(results, metric, seed_axis, out_png, x_key, prefix):
    from repro.core.sweeps import KNOWN_AXES
    results = _load_rows(results)
    if not results:
        return []
    group_keys = [k for k in KNOWN_AXES
                  if k != seed_axis and k in results[0]]
    return _banded_curves(results, group_keys, metric, out_png,
                          prefix=prefix, x_key=x_key)


def _load_rows(results):
    if isinstance(results, (str, bytes)) or hasattr(results, "read_text"):
        from repro.runtime.campaign import read_results
        return read_results(results)
    return results


def _fmt_coord(k, v) -> str:
    return f"{k}={v:g}" if isinstance(v, (int, float)) else f"{k}={v}"


def _banded_curves(results, group_keys, metric, out_png, prefix,
                   x_key=None):
    """Shared tidy-rows -> mean±band grouping behind the figure entries.

    ``x_key`` (default: the round index) picks the x-axis column — curves
    still group and aggregate per round (the deterministic alignment key),
    then plot each round at the group's mean ``x_key`` value, which is how
    the time-/bytes-to-accuracy figures reuse the same banding."""
    import collections

    groups = collections.defaultdict(lambda: collections.defaultdict(list))
    xs = collections.defaultdict(lambda: collections.defaultdict(list))
    for r in results:
        if metric not in r or (x_key is not None and x_key not in r):
            continue
        g = tuple((k, r.get(k)) for k in group_keys)
        groups[g][int(r["round"])].append(float(r[metric]))
        if x_key is not None:
            xs[g][int(r["round"])].append(float(r[x_key]))
    out = []
    for g, per_round in sorted(groups.items(), key=str):
        rounds = sorted(per_round)
        mean = np.asarray([np.mean(per_round[r]) for r in rounds])
        std = np.asarray([np.std(per_round[r]) for r in rounds])
        x = (rounds if x_key is None
             else [float(np.mean(xs[g][r])) for r in rounds])
        label = ",".join(_fmt_coord(k, v) for k, v in g) or "all"
        print(f"{prefix}_{label},{len(rounds)},"
              f"{metric}_final={mean[-1]:.4f}±{std[-1]:.4f};"
              f"n_runs={len(per_round[rounds[0]])}", flush=True)
        out.append({"group": dict(g), "rounds": rounds, "x": list(x),
                    "mean": mean.tolist(), "std": std.tolist()})
    if out_png and out:
        try:
            import matplotlib
            matplotlib.use("Agg")
            import matplotlib.pyplot as plt
        except ImportError:
            return out
        fig, ax = plt.subplots(figsize=(6, 4))
        for curve in out:
            m, s = np.asarray(curve["mean"]), np.asarray(curve["std"])
            label = ",".join(_fmt_coord(k, v)
                             for k, v in curve["group"].items())
            line, = ax.plot(curve["x"], m, label=label or "all")
            ax.fill_between(curve["x"], m - s, m + s, alpha=0.2,
                            color=line.get_color())
        ax.set_xlabel(x_key or "round")
        ax.set_ylabel(metric)
        ax.legend(fontsize=7)
        fig.tight_layout()
        fig.savefig(out_png, dpi=120)
        plt.close(fig)
    return out


def fig12_scale(rounds=3, sizes=(100, 250, 500, 1000)):
    """Paper Fig. 12 / RQ7: logreg at 100-1000 virtual clients."""
    out = []
    for n in sizes:
        fl = FLConfig(strategy="fedavg", n_clients=n, local_epochs=1,
                      client_lr=0.2, partition="iid", seed=0)
        t0 = time.time()
        _, logger = run_fl(fl, "flsim-logreg", n_items=max(2 * n, 512),
                           rounds=rounds, batch=8, run_name=f"scale{n}")
        out.append(_fmt(f"fig12_{n}clients", logger,
                        extra=f";wall_s={time.time()-t0:.1f}"))
    return out
