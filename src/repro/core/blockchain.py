"""Pluggable blockchain (paper §2.4, RQ4) — host-side hash-chain ledger.

The paper plugs Ethereum / Hyperledger Fabric behind a Blockchain API with
three user extension points: a platform wrapper, smart contracts, and an
orchestration script. Real chains are I/O, not FLOPs — here the pluggable
boundary is the ``LedgerBackend`` protocol, with an in-process hash chain as
the default backend. It provides the paper's five benefits: parameter
verification, traceable decision-making, global-model provenance, reputation
scores, and (poisoning-)attack detection hooks.

"Smart contracts" are the consensus callables from core/consensus.py
registered by name — executing consensus "on-chain" means recording its
inputs/outputs in a block.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import time
from typing import Any, Callable, Optional, Protocol

import numpy as np


def param_digest(tree) -> str:
    """Exact SHA256 over the concatenated parameter bytes (host-side)."""
    import jax
    h = hashlib.sha256()
    for leaf in jax.tree.leaves(tree):
        h.update(np.asarray(leaf).tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class Block:
    """One ledger entry; ``hash`` chains over ``prev_hash`` via SHA256."""

    index: int
    round: int
    kind: str                  # "aggregate" | "consensus" | "global"
    payload: dict
    prev_hash: str
    timestamp: float = 0.0
    hash: str = ""

    def compute_hash(self) -> str:
        """SHA256 over the canonical JSON body (excludes ``hash`` itself)."""
        body = json.dumps(
            {"i": self.index, "r": self.round, "k": self.kind,
             "p": self.payload, "prev": self.prev_hash, "t": self.timestamp},
            sort_keys=True)
        return hashlib.sha256(body.encode()).hexdigest()


class LedgerBackend(Protocol):
    """Pluggable chain interface (swap in a real chain here)."""

    def append(self, round: int, kind: str, payload: dict) -> str:
        """Append a block and return its hash."""
        ...

    def verify(self) -> bool:
        """Check the whole chain's hash links."""
        ...

    def blocks(self) -> list:
        """Return all blocks, genesis first."""
        ...


class HashChainLedger:
    """Default in-process backend."""

    def __init__(self):
        genesis = Block(0, -1, "genesis", {}, "0" * 64, 0.0)
        genesis.hash = genesis.compute_hash()
        self._chain = [genesis]
        self._clock = 0.0
        self.reputation: dict[str, float] = {}

    def append(self, round: int, kind: str, payload: dict) -> str:
        """Append a ``(round, kind, payload)`` block; returns its hash."""
        self._clock += 1.0          # logical clock: deterministic chains
        b = Block(len(self._chain), round, kind, payload,
                  self._chain[-1].hash, self._clock)
        b.hash = b.compute_hash()
        self._chain.append(b)
        return b.hash

    def verify(self) -> bool:
        """Re-hash every block and check the prev-hash links."""
        for prev, cur in zip(self._chain, self._chain[1:]):
            if cur.prev_hash != prev.hash or cur.hash != cur.compute_hash():
                return False
        return True

    def blocks(self) -> list:
        """Return a copy of the chain, genesis first."""
        return list(self._chain)

    # -- FL-specific conveniences ---------------------------------------
    def record_aggregate(self, round: int, worker: str, params) -> str:
        """Record a worker's aggregate-parameter digest for ``round``."""
        return self.append(round, "aggregate",
                           {"worker": worker, "digest": param_digest(params)})

    def record_consensus(self, round: int, contract: str, chosen_digest: str,
                         worker_digests: dict) -> str:
        """Record a consensus outcome and update worker reputations."""
        # reputation: workers whose digest lost the vote get penalized
        for w, d in worker_digests.items():
            rep = self.reputation.get(w, 1.0)
            self.reputation[w] = rep + (0.1 if d == chosen_digest else -0.25)
        return self.append(round, "consensus",
                           {"contract": contract, "chosen": chosen_digest,
                            "workers": worker_digests})

    def record_global(self, round: int, params) -> str:
        """Record the digest of the round's accepted global model."""
        return self.append(round, "global",
                           {"digest": param_digest(params)})

    def provenance(self, digest_: str) -> list:
        """Return every block whose payload mentions ``digest_``."""
        return [b for b in self._chain
                if digest_ in json.dumps(b.payload)]


def get_ledger(kind: str) -> Optional[HashChainLedger]:
    """Resolve a ledger backend by name (``none`` | ``hashchain``)."""
    if kind in ("none", None):
        return None
    if kind == "hashchain":
        return HashChainLedger()
    raise KeyError(f"unknown blockchain backend {kind!r} "
                   "(plug real chains by implementing LedgerBackend)")
