"""Multi-worker aggregation consensus (paper §2.5, RQ3, Fig. 10).

Several workers each produce an aggregate; a consensus callable picks the
next global model. Mirrors the paper's 4-phase pipeline:
  (1) local parameter sharing  (2) aggregated-parameter voting
  (3) final global parameter   (4) distribution.

Runs in-graph: W is small, aggregates are stacked on a leading worker dim.
Digest voting uses a deterministic random-projection fingerprint (the host
ledger keeps exact SHA256, see blockchain.py). Byzantine workers are
simulated via a poison transform.

The consensus callable signature matches the paper's Fig. 5:
  consensus(aggregated_models: (W, ...), extra: dict) -> chosen model
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp


@functools.lru_cache(maxsize=None)
def _projection(leaf_idx: int, width: int, n_proj: int):
    """Per-leaf random projection matrix, built once per (leaf, shape).

    Hoisted out of the per-call path: inside jit these become baked
    constants instead of per-call PRNG + normal ops, and repeated host
    calls reuse the cached array."""
    return jax.random.normal(jax.random.PRNGKey(leaf_idx), (n_proj, width))


def digest_nbytes(n_proj: int = 4) -> int:
    """Wire bytes of one digest vote: ``n_proj`` f32 projections (the comms
    plane bills consensus voting at this size, phase 2 of the pipeline)."""
    return 4 * n_proj


def digest(tree, n_proj: int = 4) -> jnp.ndarray:
    """Deterministic fingerprint: projections of the flattened pytree."""
    acc = jnp.zeros((n_proj,), jnp.float32)
    for i, leaf in enumerate(jax.tree.leaves(tree)):
        f = leaf.astype(jnp.float32).reshape(-1)
        width = min(f.shape[0], 128)
        acc = acc + _projection(i, width, n_proj) @ f[:width]
    return acc


def majority_digest(aggs, extra):
    """Pick the aggregate whose (quantized) digest has the most matches —
    honest majority nullifies minority poisoners (Chowdhury et al. [13]).
    The per-worker digests run as one vmap over the stacked worker dim."""
    digs = jax.vmap(digest)(aggs)                              # (W, P)
    q = jnp.round(digs * 1e4) / 1e4
    same = (jnp.abs(q[:, None] - q[None, :]) < 1e-3).all(-1)   # (W, W)
    votes = same.sum(-1)
    winner = jnp.argmax(votes)
    return jax.tree.map(lambda t: t[winner], aggs)


def median_select(aggs, extra):
    """Coordinate-wise median across workers (robust aggregation)."""
    return jax.tree.map(lambda t: jnp.median(t, axis=0), aggs)


def trimmed_mean(aggs, extra):
    """Coordinate-wise trimmed mean over worker parameter trees."""
    trim = int(extra.get("trim", 1))
    def f(t):
        s = jnp.sort(t, axis=0)
        W = t.shape[0]
        return s[trim:W - trim].mean(0) if W > 2 * trim else t.mean(0)
    return jax.tree.map(f, aggs)


CONSENSUS_REGISTRY: dict[str, Callable] = {
    "majority_digest": majority_digest,
    "median": median_select,
    "trimmed_mean": trimmed_mean,
}


def poison(tree, scale: float = 10.0, rng=None):
    """Model-poisoning transform for byzantine-worker simulation."""
    rng = jax.random.PRNGKey(666) if rng is None else rng
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(rng, len(leaves))
    return jax.tree.unflatten(treedef, [
        l + scale * jax.random.normal(k, l.shape, jnp.float32).astype(l.dtype)
        for l, k in zip(leaves, keys)])


@dataclasses.dataclass(frozen=True)
class MultiWorkerAggregator:
    """Wraps a base aggregate with W redundant workers + consensus."""
    n_workers: int
    byzantine: int
    consensus: str = "majority_digest"
    poison_scale: float = 3.0

    def run(self, agg_delta, rng):
        """agg_delta: the honest aggregate (all workers see the same client
        deltas). Byzantine workers poison theirs; consensus picks one."""
        fn = CONSENSUS_REGISTRY[self.consensus]
        versions = []
        for w in range(self.n_workers):
            if w < self.byzantine:
                versions.append(poison(agg_delta, self.poison_scale,
                                       jax.random.fold_in(rng, w)))
            else:
                versions.append(agg_delta)
        stacked = jax.tree.map(lambda *ts: jnp.stack(ts), *versions)
        return fn(stacked, {})
