"""Job configuration loader (paper Fig. 2).

A job YAML mirrors the paper's six sections: dataset, consensus, clusters,
strategy, node defaults, node configs. ``load_job`` turns it into the typed
configs the rest of the system consumes; ``scaffold`` is the Job
Orchestrator entry (paper component 1): it resolves the model, strategy,
topology, dataset pipeline and fault model from one file.

Every section ``load_job`` consumes is validated against its known keys —
a typo like ``cleint_lr`` fails loudly with a near-miss suggestion instead
of silently running with the default. A ``sweep:`` section expands the job
into a campaign (``core/sweeps.py`` + ``runtime/campaign.py``).
"""
from __future__ import annotations

import dataclasses
import difflib
import pathlib
from typing import Any, Optional

import yaml

from repro.configs.base import FLConfig, get_config
from repro.core import sweeps
from repro.core.strategies import get_strategy
from repro.core.topology import get_topology
from repro.core.blockchain import get_ledger
from repro.data.pipeline import (SyntheticLM, SyntheticPopulation,
                                 SyntheticVision)
from repro.models import model_zoo
from repro.runtime.clock import ClientSystemModel
from repro.runtime.faults import FaultModel


@dataclasses.dataclass
class Job:
    """A validated FL job: raw config dict plus resolved typed sections."""
    name: str
    fl: FLConfig
    arch: str
    model: Any
    strategy: Any
    topology: Any
    dataset: Any
    ledger: Any
    fault: FaultModel
    raw: dict
    sweep: Optional[sweeps.SweepSpec] = None


_FL_KEYS = {f.name for f in dataclasses.fields(FLConfig)}
_CSM_KEYS = {f.name for f in dataclasses.fields(ClientSystemModel)}
_DATASET_KEYS = {"dataset", "n_items", "distribution", "items_per_client"}
_MODEL_KEYS = {"arch", "reduced"}
_STRATEGY_KEYS = {"strategy", "train_params", "aggregator_params"}
# paper Fig. 2's six sections (clusters / node sections are accepted but
# not yet consumed) + model, the campaign sweep, and the flight recorder
_TOP_KEYS = {"name", "model", "dataset", "consensus", "strategy", "runtime",
             "sweep", "clusters", "node_defaults", "node_configs",
             "telemetry", "probes", "comms"}
# flight-recorder knobs (repro/telemetry): presence of the section turns
# the recorder on (enabled: false to keep a section but switch it off)
_TELEMETRY_KEYS = {"enabled", "out_dir", "profile_chunks", "cost_analysis"}
# round-probe knobs (core/probes.py): presence of the section compiles the
# probe outputs into the round/event scans (enabled: false to switch off)
_PROBES_KEYS = {"enabled", "out_dir", "on_divergence"}
# comms-observatory knobs (telemetry/comms.py): host-side wire-traffic
# accounting; the LinkModel knobs themselves are runtime: section fields
_COMMS_KEYS = {"enabled", "out_dir", "pods"}


def _check_keys(section_name: str, section, allowed) -> None:
    """Fail on unknown keys with a did-you-mean hint (no silent drops)."""
    if section is not None and not isinstance(section, dict):
        raise TypeError(f"job {section_name!r} section must be a mapping, "
                        f"got {type(section).__name__}: {section!r}")
    for k in section or {}:
        if k not in allowed:
            hint = difflib.get_close_matches(k, sorted(allowed), n=1)
            suffix = (f" — did you mean {hint[0]!r}?" if hint
                      else f"; known keys: {sorted(allowed)}")
            raise KeyError(
                f"unknown key {k!r} in job {section_name!r} section{suffix}")


def make_dataset(raw: dict, fl: FLConfig, cfg=None):
    """Dataset factory, seeded by ``fl.seed`` — campaigns call this per
    trajectory so a swept seed re-derives the root data."""
    ds = raw.get("dataset", {}) or {}
    kind = ds.get("dataset", "synthetic_vision")
    if kind == "synthetic_vision":
        kw = {}
        if cfg is not None and cfg.family == "small":
            # flsim-logreg is MNIST-shaped; cnn/mlp keep the CIFAR default
            from repro.models.small import input_shape
            kw["shape"] = input_shape(cfg)
        return SyntheticVision(n_items=ds.get("n_items", 1024), seed=fl.seed,
                               **kw)
    if kind == "synthetic_lm":
        vocab = (cfg.padded_vocab if cfg is not None
                 and cfg.family != "small" else 512)
        return SyntheticLM(vocab=vocab, seed=fl.seed)
    if kind == "synthetic_population":
        # shard-on-demand population for the streaming client plane: sized
        # by fl.n_clients, never materialized — requires streaming: true
        kw = {}
        if cfg is not None and cfg.family == "small":
            from repro.models.small import input_shape
            kw["shape"] = input_shape(cfg)
        return SyntheticPopulation(
            n_clients=fl.n_clients,
            items_per_client=ds.get("items_per_client", 8),
            seed=fl.seed, **kw)
    raise KeyError(f"unknown dataset {kind!r}")


def validate_cohort(fl: FLConfig) -> None:
    """Reject cohort/ragged combinations that would silently misbehave.

    Without this, ``cohort > n_clients`` silently clamps through the mask's
    permutation pool, an undersized ``max_cohort`` would drop sampled
    clients on the floor, and ``streaming`` without ragged slots has no
    per-chunk working set to stream. Campaigns validate every expanded
    lane config through the same function.
    """
    if fl.cohort < 0 or fl.max_cohort < 0:
        raise ValueError(f"cohort={fl.cohort} / max_cohort={fl.max_cohort} "
                         "must be >= 0")
    if fl.cohort > fl.n_clients:
        raise ValueError(
            f"cohort={fl.cohort} exceeds n_clients={fl.n_clients}; an "
            "oversized cohort would silently clamp to the population — "
            "lower cohort or raise n_clients")
    target = fl.cohort or fl.n_clients
    if fl.max_cohort and fl.max_cohort < target:
        raise ValueError(
            f"max_cohort={fl.max_cohort} is smaller than the per-round "
            f"cohort ({target}); every sampled client needs a slab slot — "
            "raise max_cohort or lower cohort (cohort=0 samples all "
            "n_clients)")
    if fl.streaming and not fl.max_cohort:
        raise ValueError(
            "streaming: true requires ragged cohorts (max_cohort > 0) — "
            "resident staging has no per-chunk working set to stream")


def make_fault(raw: dict, fl: FLConfig) -> ClientSystemModel:
    """ClientSystemModel is a FaultModel: the sync path only reads the fault
    fields, the async virtual clock also reads the system ones. Seeded by
    ``fl.seed`` (campaigns rebuild per trajectory)."""
    rt = raw.get("runtime", {}) or {}
    return ClientSystemModel(
        drop_prob=rt.get("drop_prob", 0.0),
        straggler_prob=rt.get("straggler_prob", 0.0),
        straggler_slowdown=rt.get("straggler_slowdown", 4.0),
        seed=fl.seed,
        mean_duration=rt.get("mean_duration", 1.0),
        duration_sigma=rt.get("duration_sigma", 0.25),
        rate_spread=rt.get("rate_spread", 0.0),
        availability=rt.get("availability", 1.0),
        up_mbps=rt.get("up_mbps", 100.0),
        down_mbps=rt.get("down_mbps", 400.0),
        link_tiers=rt.get("link_tiers", 1),
        link_tier_factor=rt.get("link_tier_factor", 0.5),
        latency_s=rt.get("latency_s", 0.01))


def rebind(job: Job, fl: FLConfig) -> Job:
    """A copy of ``job`` re-resolved around a different FLConfig.

    The campaign planner expands one job into per-bucket configs whose
    categorical coordinates (strategy, topology, mode, ...) differ from the
    base; the derived objects (strategy, topology, dataset, fault) must be
    rebuilt from the new config. The model (same arch for every lane) and
    the ledger (one provenance chain per campaign) are shared by reference.
    """
    return dataclasses.replace(
        job, fl=fl,
        strategy=get_strategy(fl),
        topology=get_topology(fl.topology, fl.gossip_steps),
        dataset=make_dataset(job.raw, fl, getattr(job.model, "cfg", None)),
        fault=make_fault(job.raw, fl))


def load_job(path_or_dict) -> Job:
    """Load and validate a job from a YAML path or config dict."""
    if isinstance(path_or_dict, (str, pathlib.Path)):
        raw = yaml.safe_load(pathlib.Path(path_or_dict).read_text())
    else:
        raw = dict(path_or_dict)

    strat = raw.get("strategy", {}) or {}
    ds = raw.get("dataset", {}) or {}
    cons = raw.get("consensus", {}) or {}
    rt = raw.get("runtime", {}) or {}
    # a typo'd *section* name ("runtim:", "sweeps:") would silently drop
    # the whole section, the same failure class as a typo'd key inside one
    _check_keys("top-level", raw, _TOP_KEYS)
    _check_keys("strategy", strat, _STRATEGY_KEYS)
    _check_keys("strategy.train_params", strat.get("train_params"), _FL_KEYS)
    _check_keys("strategy.aggregator_params", strat.get("aggregator_params"),
                _FL_KEYS)
    _check_keys("consensus", cons, _FL_KEYS)
    _check_keys("dataset", ds, _DATASET_KEYS)
    _check_keys("dataset.distribution", ds.get("distribution"), _FL_KEYS)
    _check_keys("model", raw.get("model"), _MODEL_KEYS)
    _check_keys("runtime", rt, _FL_KEYS | _CSM_KEYS)
    _check_keys("telemetry", raw.get("telemetry"), _TELEMETRY_KEYS)
    _check_keys("probes", raw.get("probes"), _PROBES_KEYS)
    _check_keys("comms", raw.get("comms"), _COMMS_KEYS)
    if raw.get("comms"):
        # value validation (pods >= 1) lives in CommsSpec; running it here
        # fails at load time, naming the YAML
        from repro.telemetry.comms import CommsSpec
        c = raw["comms"]
        CommsSpec(enabled=bool(c.get("enabled", True)),
                  out_dir=c.get("out_dir"), pods=int(c.get("pods", 1)))
    if raw.get("probes"):
        # value validation (on_divergence enum, freeze-needs-enabled) lives
        # in ProbeSpec; running it here fails at load time, naming the YAML
        from repro.core.probes import ProbeSpec
        p = raw["probes"]
        ProbeSpec(enabled=bool(p.get("enabled", True)),
                  out_dir=p.get("out_dir"),
                  on_divergence=p.get("on_divergence", "report"))

    flkw = {}
    for section in (strat.get("train_params", {}),
                    strat.get("aggregator_params", {}),
                    cons, ds.get("distribution", {}), rt):
        for k, v in (section or {}).items():
            if k in _FL_KEYS:
                flkw[k] = v
    if "strategy" in strat:
        flkw["strategy"] = strat["strategy"]
    fl = FLConfig(**flkw)
    validate_cohort(fl)

    arch = raw.get("model", {}).get("arch", "flsim-cnn")
    reduced = raw.get("model", {}).get("reduced", False)
    cfg = get_config(arch)
    if reduced:
        from repro.configs.reduce import reduced_config
        cfg = reduced_config(cfg)
    model = model_zoo.build(cfg)

    return Job(
        name=raw.get("name", "job"),
        fl=fl, arch=arch, model=model,
        strategy=get_strategy(fl),
        topology=get_topology(fl.topology, fl.gossip_steps),
        dataset=make_dataset(raw, fl, cfg),
        ledger=get_ledger(fl.blockchain),
        fault=make_fault(raw, fl),
        raw=raw,
        sweep=sweeps.parse_sweep(raw.get("sweep")),
    )
