"""Job configuration loader (paper Fig. 2).

A job YAML mirrors the paper's six sections: dataset, consensus, clusters,
strategy, node defaults, node configs. ``load_job`` turns it into the typed
configs the rest of the system consumes; ``scaffold`` is the Job
Orchestrator entry (paper component 1): it resolves the model, strategy,
topology, dataset pipeline and fault model from one file.
"""
from __future__ import annotations

import dataclasses
import pathlib
from typing import Any, Optional

import yaml

from repro.configs.base import FLConfig, get_config
from repro.core.strategies import get_strategy
from repro.core.topology import get_topology
from repro.core.blockchain import get_ledger
from repro.data.pipeline import SyntheticLM, SyntheticVision
from repro.models import model_zoo
from repro.runtime.clock import ClientSystemModel
from repro.runtime.faults import FaultModel


@dataclasses.dataclass
class Job:
    name: str
    fl: FLConfig
    arch: str
    model: Any
    strategy: Any
    topology: Any
    dataset: Any
    ledger: Any
    fault: FaultModel
    raw: dict


_FL_KEYS = {f.name for f in dataclasses.fields(FLConfig)}


def load_job(path_or_dict) -> Job:
    if isinstance(path_or_dict, (str, pathlib.Path)):
        raw = yaml.safe_load(pathlib.Path(path_or_dict).read_text())
    else:
        raw = dict(path_or_dict)

    strat = raw.get("strategy", {})
    ds = raw.get("dataset", {})
    cons = raw.get("consensus", {})
    flkw = {}
    for section in (strat.get("train_params", {}),
                    strat.get("aggregator_params", {}),
                    cons, ds.get("distribution", {}),
                    raw.get("runtime", {})):
        for k, v in (section or {}).items():
            if k in _FL_KEYS:
                flkw[k] = v
    if "strategy" in strat:
        flkw["strategy"] = strat["strategy"]
    fl = FLConfig(**flkw)

    arch = raw.get("model", {}).get("arch", "flsim-cnn")
    reduced = raw.get("model", {}).get("reduced", False)
    cfg = get_config(arch)
    if reduced:
        from repro.configs.reduce import reduced_config
        cfg = reduced_config(cfg)
    model = model_zoo.build(cfg)

    kind = ds.get("dataset", "synthetic_vision")
    if kind == "synthetic_vision":
        dataset = SyntheticVision(n_items=ds.get("n_items", 1024),
                                  seed=fl.seed)
    elif kind == "synthetic_lm":
        dataset = SyntheticLM(vocab=cfg.padded_vocab
                              if cfg.family != "small" else 512, seed=fl.seed)
    else:
        raise KeyError(f"unknown dataset {kind!r}")

    # ClientSystemModel is a FaultModel: the sync path only reads the fault
    # fields, the async virtual clock also reads the system ones.
    rt = raw.get("runtime", {})
    fault = ClientSystemModel(
        drop_prob=rt.get("drop_prob", 0.0),
        straggler_prob=rt.get("straggler_prob", 0.0),
        straggler_slowdown=rt.get("straggler_slowdown", 4.0),
        seed=fl.seed,
        mean_duration=rt.get("mean_duration", 1.0),
        duration_sigma=rt.get("duration_sigma", 0.25),
        rate_spread=rt.get("rate_spread", 0.0),
        availability=rt.get("availability", 1.0))
    return Job(
        name=raw.get("name", "job"),
        fl=fl, arch=arch, model=model,
        strategy=get_strategy(fl),
        topology=get_topology(fl.topology, fl.gossip_steps),
        dataset=dataset,
        ledger=get_ledger(fl.blockchain),
        fault=fault,
        raw=raw,
    )
