"""Pytree <-> (N,) flat packing for the quant_aggregate kernel layout.

``kernels/quant_aggregate`` reduces client deltas laid out as a dense
``(C, N) int8`` matrix plus ``(C, N/qblock) f32`` block scales. Model deltas
are pytrees of arbitrarily-shaped leaves, so the compressed path needs a
deterministic flatten: each leaf is raveled and zero-padded up to a whole
number of quantization blocks, then the padded leaves are concatenated in
``jax.tree`` leaf order.

Per-leaf padding (rather than one pad at the end) is load-bearing: it keeps
every quantization block contained within a single leaf, so the packed
quantizer produces bitwise the same (q, scale) stream as quantizing each
leaf on its own — which is exactly what the unpacked reference roundtrip
(``strategies/compressed._roundtrip_int8``) does. Error-feedback residuals
computed against either representation therefore agree bit for bit.

The pack spec (offsets, padded sizes) is a pure function of the tree
*structure*, known at trace time; nothing here inspects runtime values.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

QBLOCK = 256   # quantization block; matches _roundtrip_int8's default


class PackedDelta(NamedTuple):
    """A block-quantized flat delta: what crosses the simulated network.

    ``q``: (N,) int8 quantized values (N a multiple of the block size);
    ``scale``: (N // qblock,) f32 per-block dequant scales.
    NamedTuple => a pytree, so PackedDelta flows through vmap/scan/cond and
    picks up leading batch dims ((C, N) / (C, N/qblock)) like any leaf.
    """
    q: jax.Array
    scale: jax.Array


def _padded_size(n: int, qblock: int) -> int:
    return n + (-n) % qblock


def packed_size(template, qblock: int = QBLOCK) -> tuple[int, int]:
    """(N, n_blocks) of the packed representation of ``template``'s tree."""
    n = sum(_padded_size(leaf.size, qblock)
            for leaf in jax.tree.leaves(template))
    return n, n // qblock


def packed_nbytes(template, qblock: int = QBLOCK) -> int:
    """Wire bytes of one packed delta: 1 byte per int8 value + 4 bytes per
    f32 block scale — what a ``compression: int8`` client actually sends
    (~dense/4 + 1/qblock scale overhead; the comms plane's int8 payload)."""
    n, n_blocks = packed_size(template, qblock)
    return n + 4 * n_blocks


def pack_tree(tree, qblock: int = QBLOCK) -> jax.Array:
    """Flatten a pytree to (N,) f32, zero-padding each leaf to whole blocks."""
    pieces = []
    for leaf in jax.tree.leaves(tree):
        flat = leaf.reshape(-1).astype(jnp.float32)
        pad = (-flat.shape[0]) % qblock
        pieces.append(jnp.pad(flat, (0, pad)) if pad else flat)
    return pieces[0] if len(pieces) == 1 else jnp.concatenate(pieces)


def quantize_tree(tree, qblock: int = QBLOCK) -> PackedDelta:
    """Block-quantize a delta pytree into the kernel's packed layout."""
    from repro.kernels import ref as kref
    q, sc = kref.quantize_blockwise_ref(pack_tree(tree, qblock), block=qblock)
    return PackedDelta(q=q, scale=sc)


def dequant_flat(pd: PackedDelta) -> jax.Array:
    """(N,) f32 dequantized values; same arithmetic order as the unpacked
    reference roundtrip (int8 -> f32, then one multiply per block)."""
    n, nblocks = pd.q.shape[-1], pd.scale.shape[-1]
    qblock = n // nblocks
    deq = pd.q.astype(jnp.float32).reshape(*pd.q.shape[:-1], nblocks, qblock)
    return (deq * pd.scale[..., None]).reshape(pd.q.shape)


def unpack_tree(flat, template, qblock: int = QBLOCK):
    """Invert pack_tree: slice (N,) back into ``template``-shaped f32 leaves
    (padding lanes dropped). Caller casts to the target dtype."""
    leaves, treedef = jax.tree.flatten(template)
    out, off = [], 0
    for leaf in leaves:
        out.append(flat[off:off + leaf.size].reshape(leaf.shape))
        off += _padded_size(leaf.size, qblock)
    return jax.tree.unflatten(treedef, out)
