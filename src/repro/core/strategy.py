"""FL Strategy base — the JAX rendering of FLsim's ``LearnStrategyBase``.

The paper's strategy class bundles train() / aggregate() / test() plus local
state. Here a Strategy is a set of *pure hooks* over generic pytrees, so one
strategy definition works for a 3-layer CNN and a 480B MoE alike (the paper's
"library agnosticism" recast as model/pytree agnosticism):

  local_loss       — decorate the base loss (FedProx proximal term, MOON ...)
  grad_transform   — adjust the local gradient (SCAFFOLD control variates)
  postprocess      — transform the client delta before aggregation (DP, int8)
  aggregate_update — turn the aggregated delta + server state into new params
  *_state_init     — per-client / server state (momenta, control variates)

Hooks run inside jit (spatial: under shard_map+vmap; temporal: inside the
cohort scan), so they must be jax-pure.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig

PyTree = Any


def tree_zeros_like(t):
    """Pytree of zeros matching ``tree``'s leaves."""
    return jax.tree.map(jnp.zeros_like, t)


def tree_add(a, b, scale=1.0):
    """Leafwise ``a + b`` over two matching pytrees."""
    return jax.tree.map(lambda x, y: x + scale * y, a, b)


def tree_sub(a, b):
    """Leafwise ``a - b`` over two matching pytrees."""
    return jax.tree.map(lambda x, y: x - y, a, b)


def tree_scale(a, s):
    """Leafwise ``s * a`` over a pytree."""
    return jax.tree.map(lambda x: x * s, a)


def global_norm(t):
    # +tiny keeps the sqrt differentiable at exactly-zero trees (MOON's
    # first-round prev-drift; otherwise grad(sqrt)(0) = nan)
    """Global L2 norm over a pytree's leaves."""
    return jnp.sqrt(1e-24 + sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                                for x in jax.tree.leaves(t)))


@dataclasses.dataclass(frozen=True)
class Strategy:
    """FedAvg — weighted parameter averaging (McMahan et al.). Base class."""
    fl: FLConfig
    name: str = "fedavg"

    # -- state ---------------------------------------------------------
    def server_state_init(self, params) -> PyTree:
        """Initial server-side optimizer state (default: none)."""
        return ()

    def client_state_init(self, params) -> PyTree:
        """Initial per-client carried state (default: none)."""
        return ()

    # -- local training hooks -------------------------------------------
    def local_loss(self, base_loss: Callable, params, global_params, batch,
                   client_state, rng):
        """base_loss(params, batch, rng) -> (loss, metrics); override to add
        regularizers that see the global params."""
        return base_loss(params, batch, rng)

    def grad_transform(self, grad, client_state, server_state):
        """Hook transforming local gradients before the SGD step."""
        return grad

    def client_state_update(self, client_state, server_state, delta,
                            n_local_steps, lr):
        """Hook producing the client state carried to the next round."""
        return client_state

    # -- delta pipeline ---------------------------------------------------
    def postprocess(self, delta, client_state, rng):
        """Client-side delta transform (clip/noise/compress). Returns
        (delta, new_client_state)."""
        return delta, client_state

    @property
    def packs_deltas(self) -> bool:
        """True when clients emit ``packing.PackedDelta`` (int8 + block
        scales) via ``postprocess_packed`` instead of a param-shaped delta —
        the drivers then aggregate through ``kernels/ops.quant_aggregate``
        rather than a dense f32 mean. A static property of the bound config
        (compression is part of the program signature, so the planner never
        mixes packed and unpacked lanes in one bucket)."""
        return False

    def postprocess_packed(self, delta, client_state, rng):
        """Packed counterpart of ``postprocess``: returns
        (PackedDelta, new_client_state). Only called when ``packs_deltas``."""
        raise NotImplementedError(
            f"{self.name}: packs_deltas is True but postprocess_packed "
            "is not implemented")

    # -- server -----------------------------------------------------------
    def server_update(self, params, agg_delta, server_state):
        """params + aggregated delta (server_lr scaled). Returns
        (new_params, new_server_state)."""
        lr = self.fl.server_lr
        return tree_add(params, agg_delta, lr), server_state

    def describe(self) -> str:
        """Human-readable one-line description of the strategy config."""
        return f"{self.name}(server_opt={self.fl.server_optimizer})"


def client_sgd_step(params, grad, lr, momentum_state=None, momentum=0.0):
    """The client-side optimizer used by local epochs."""
    if momentum and momentum_state is not None:
        new_m = jax.tree.map(lambda m, g: momentum * m + g, momentum_state, grad)
        new_p = jax.tree.map(lambda p, m: p - lr * m.astype(p.dtype),
                             params, new_m)
        return new_p, new_m
    return jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype),
                        params, grad), momentum_state
