"""Wire-level network model: payload bytes, link plans, simulated time.

The comms observatory (``telemetry/comms.py``) needs three pure-host
ingredients, all collected here so the byte-accounting rules live in one
place and stay testable without an executor:

1. **Payload sizes** — how many bytes one client exchange costs, from the
   actual representations the drivers move: dense f32 state/deltas, the
   packed int8 ``PackedDelta`` (``packing.packed_nbytes``: 1 byte/value +
   4 bytes/block scale), topk sparse sends ((int32 index, f32 value) pairs),
   consensus digest votes (``consensus.digest_nbytes``) and full f32
   worker-aggregate sharing, gossip neighbour exchanges
   (``topology.GOSSIP_NEIGHBORS`` sends per client per step), hierarchical
   edge->cloud backbone hops, and blockchain block records.

2. **LinkModel draws** — per-client up/down bandwidth and latency from the
   ``ClientSystemModel`` link fields. Tier assignment comes from the
   ``clock._TAG_LINK`` Philox stream: a *new* tag, so link draws never
   perturb the rate/jitter/straggler/availability columns — schedules are
   bitwise identical with the link model on or off, and prefix-stable in
   the number of clients drawn.

3. **Simulated wall-clock** — ``LaneComms`` composes transfer time with the
   virtual clock's compute durations (``clock._dur_column``, the same
   per-task streams the async schedule consumed):

   - sync round makespan = max over the kept cohort of
     (downlink + compute + uplink) + aggregation hop (one extra latency per
     tier past the server: hierarchical backbone, consensus exchange);
   - async reuses ``EventSchedule.vtime`` shifted per event by the client's
     cumulative transfer time ((task+1) round-trips), folded monotone by a
     running max.

   On the FedAvg-identity configuration (equal speeds, FedBuff buffer ==
   cohort) the two compositions agree — the same collapse the schedule
   itself guarantees for params (tests/test_comms.py).

Everything here is host-side numpy over shapes and schedule arrays — zero
device code, so comms accounting can never perturb a trajectory.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import numpy as np

from repro.configs.base import FLConfig
from repro.core.consensus import digest_nbytes
from repro.core.packing import QBLOCK, packed_nbytes
from repro.core.topology import GOSSIP_NEIGHBORS
from repro.runtime.clock import (ClientSystemModel, _TAG_LINK, _column,
                                 _dur_column, client_rates)

# one blockchain block record per round when a ledger is configured: the
# SHA256 param digest that crosses the simulated network (provenance is
# per-round by construction, so byte totals stay chunking-invariant even
# though the host ledger batches its writes at chunk boundaries)
BLOCK_NBYTES = 32


# ---------------------------------------------------------------------------
# payload sizes (pure functions of the param-tree shapes + FLConfig)
# ---------------------------------------------------------------------------

class _ShapeLeaf:
    """Shape-only stand-in leaf: everything the size helpers read
    (``.shape`` / ``.size``) without holding device memory."""
    __slots__ = ("shape", "size")

    def __init__(self, shape):
        self.shape = tuple(shape)
        self.size = int(math.prod(self.shape)) if self.shape else 1


def shape_template(tree, strip_leading: bool = False) -> list:
    """Shape-only copy of a param tree (a flat leaf list). The byte model
    prices ONE model's exchange: campaigns strip the stacked lane dim and
    decentralized states strip the per-client dim via ``strip_leading``."""
    return [_ShapeLeaf(leaf.shape[1:] if strip_leading else leaf.shape)
            for leaf in jax.tree.leaves(tree)]


def tree_sizes(template) -> list:
    """Per-leaf element counts of a param pytree (shape-only)."""
    return [int(math.prod(leaf.shape)) if leaf.shape else 1
            for leaf in jax.tree.leaves(template)]


def dense_nbytes(template) -> int:
    """Bytes of one dense f32 send of the whole tree (state or delta —
    every driver casts deltas to f32 before they cross the network)."""
    return 4 * sum(tree_sizes(template))


def topk_nbytes(template, topk_ratio: float) -> int:
    """Bytes of one topk sparse send: k (int32 index, f32 value) pairs."""
    n = sum(tree_sizes(template))
    k = max(int(math.ceil(float(topk_ratio) * n)), 1)
    return 8 * k


def uplink_nbytes(template, fl: FLConfig) -> int:
    """Bytes of one client's *uplink* payload under ``fl.compression``."""
    if fl.compression == "int8":
        return packed_nbytes([_ShapeLeaf(leaf.shape)
                              for leaf in jax.tree.leaves(template)],
                             QBLOCK)
    if fl.compression == "topk":
        return topk_nbytes(template, fl.topk_ratio)
    return dense_nbytes(template)


def payload_nbytes(template, fl: FLConfig) -> tuple:
    """(uplink, downlink) bytes of one client's round exchange. Downlink is
    the dense f32 global state (the server broadcasts uncompressed)."""
    return uplink_nbytes(template, fl), dense_nbytes(template)


# ---------------------------------------------------------------------------
# topology traffic matrices
# ---------------------------------------------------------------------------

def gossip_matrix(n_clients: int, state_nbytes: int,
                  gossip_steps: int = 1) -> np.ndarray:
    """(C, C) bytes sent i -> j over one round of decentralized gossip.

    The meshless ring mixes each client with its ±1 neighbours
    (``GOSSIP_NEIGHBORS`` sends per step), so the matrix is symmetric —
    every i -> j send has the j -> i reciprocal — and scales linearly with
    ``gossip_steps`` (the satellite invariants in tests/test_comms.py)."""
    C = int(n_clients)
    m = np.zeros((C, C), np.int64)
    if C < 2:
        return m
    per = int(state_nbytes) * int(gossip_steps)
    for i in range(C):
        m[i, (i + 1) % C] += per
        m[i, (i - 1) % C] += per
    return m


def hierarchical_nbytes(intra_up: int, intra_down: int, state_nbytes: int,
                        pods: int = 1) -> tuple:
    """(intra_pod, cross_pod) byte split of one hierarchical round: clients
    talk to their pod's edge aggregator (the client_server bytes), then each
    pod ships its f32 edge aggregate to the cloud and receives the global
    state back — two backbone hops per pod."""
    cross = 2 * int(pods) * int(state_nbytes)
    return int(intra_up) + int(intra_down), cross


def consensus_nbytes(fl: FLConfig, state_nbytes: int) -> int:
    """Multi-worker consensus overlay bytes per round: phase-1 full f32
    aggregate sharing (all-to-all among W workers) + phase-2 digest votes."""
    w = max(int(fl.n_workers), 1)
    if w <= 1:
        return 0
    share = w * (w - 1) * int(state_nbytes)
    votes = w * (w - 1) * digest_nbytes()
    return share + votes


def round_nbytes(template, fl: FLConfig, pods: int = 1) -> int:
    """Total wire bytes of one full-participation round — the closed-form
    the legacy ``benchmarks.flbench.comm_bytes_per_round`` now delegates to
    (masked accounting lives in ``LaneComms``)."""
    sb = dense_nbytes(template)
    C = int(fl.n_clients)
    cohort = int(fl.cohort or C)
    ledger = BLOCK_NBYTES if fl.blockchain != "none" else 0
    if fl.topology == "decentralized":
        per = GOSSIP_NEIGHBORS * int(fl.gossip_steps) * sb
        return C * per * 2 + ledger          # every send is a receive
    up, down = payload_nbytes(template, fl)
    total = cohort * (up + down) + consensus_nbytes(fl, sb) + ledger
    if fl.topology == "hierarchical":
        total += hierarchical_nbytes(0, 0, sb, pods)[1]
    return total


# ---------------------------------------------------------------------------
# LinkModel: per-client bandwidth/latency draws
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LinkPlan:
    """Materialized per-client link parameters (bytes/virtual-second)."""
    up_Bps: np.ndarray        # (C,) f64
    down_Bps: np.ndarray      # (C,) f64
    latency_s: float

    def up_time(self, nbytes) -> np.ndarray:
        """Per-client uplink transfer seconds for an ``nbytes`` payload."""
        return self.latency_s + np.asarray(nbytes, np.float64) / self.up_Bps

    def down_time(self, nbytes) -> np.ndarray:
        """Per-client downlink transfer seconds for an ``nbytes`` payload."""
        return self.latency_s + np.asarray(nbytes, np.float64) \
            / self.down_Bps


def client_links(csm: ClientSystemModel, n_clients: int) -> LinkPlan:
    """Draw the per-client link plan from the ``_TAG_LINK`` Philox stream.

    Tier t (0 = top) scales both directions by ``link_tier_factor ** t``;
    ``link_tiers == 1`` skips the draw entirely (homogeneous links). Seeded
    like every other client-system stream, so the plan is seed-pure and
    prefix-stable in ``n_clients``."""
    C = int(n_clients)
    tiers = max(int(getattr(csm, "link_tiers", 1)), 1)
    if tiers > 1:
        tier = _column(csm.seed, _TAG_LINK, 0,
                       lambda g, n: g.integers(0, tiers, n), C)
    else:
        tier = np.zeros(C, np.int64)
    factor = float(getattr(csm, "link_tier_factor", 0.5)) ** \
        tier.astype(np.float64)
    up = float(getattr(csm, "up_mbps", 100.0)) * 1e6 / 8.0 * factor
    down = float(getattr(csm, "down_mbps", 400.0)) * 1e6 / 8.0 * factor
    return LinkPlan(up_Bps=np.maximum(up, 1.0),
                    down_Bps=np.maximum(down, 1.0),
                    latency_s=float(getattr(csm, "latency_s", 0.01)))


# ---------------------------------------------------------------------------
# LaneComms: one lane's running traffic + simulated-clock accountant
# ---------------------------------------------------------------------------

# per-round columns every accountant emits (comms.csv schema, sorted into
# the tidy rows by the executor)
COMMS_COLUMNS = ("up_bytes", "down_bytes", "overlay_bytes", "makespan_s",
                 "cum_up_bytes", "cum_down_bytes", "cum_bytes", "sim_time_s")


@dataclasses.dataclass
class LaneComms:
    """Running wire-traffic + simulated wall-clock accountant for one lane.

    Stateful on purpose: cumulative counters advance strictly in round
    order, once per round, independent of how the chunk loop slices the
    horizon — which is what makes byte totals chunking-invariant (chunk=1
    == chunk=4, asserted in tests/test_comms.py). The sync path replays the
    in-program cohort mask host-side (``faults.cohort_mask`` is jittable
    *and* host-callable, the same agreement ``select_cohort`` relies on);
    the async path reads the precomputed schedule's accept flags — so byte
    counts are gated by exactly the participation the drivers computed.
    """
    fl: FLConfig
    csm: ClientSystemModel
    template: object          # param pytree (shape-only use)
    pods: int = 1

    def __post_init__(self):
        fl, C = self.fl, int(self.fl.n_clients)
        if not isinstance(self.csm, ClientSystemModel):
            self.csm = ClientSystemModel(**dataclasses.asdict(self.csm))
        self.links = client_links(self.csm, C)
        self.rate = client_rates(self.csm, C)
        self.state_nbytes = dense_nbytes(self.template)
        self.up_payload, self.down_payload = payload_nbytes(self.template,
                                                            fl)
        self._target = int(fl.cohort or C)
        # full-participation fast path: with the whole population kept and
        # no drops the in-program mask is all-ones (rank < target keeps
        # every eligible client), so the per-round replay can be skipped
        self._trivial_mask = (self._target >= C
                              and self.csm.drop_prob == 0.0)
        self.cum_up = 0
        self.cum_down = 0
        self.cum_overlay = 0
        self.cum_dense_up = 0     # uncompressed-equivalent uplink (ratio)
        self.sim_time = 0.0
        # decentralized per-client gossip bytes per round (each client
        # sends its state to GOSSIP_NEIGHBORS peers per step — and receives
        # symmetrically, per the gossip_matrix invariant)
        self._gossip_per_client = (GOSSIP_NEIGHBORS * int(fl.gossip_steps)
                                   * self.state_nbytes)
        # round-invariant pieces, hoisted out of the per-round loop (the
        # accountant runs at every chunk boundary — at chunk=1 this is the
        # BENCH_comms overhead budget): per-client link transfer time, the
        # aggregation hop, the ledger record, the per-round overlay, and
        # the decentralized per-step transfer time
        self._ledger_nbytes = (BLOCK_NBYTES if fl.blockchain != "none"
                               else 0)
        self._t_link = (self.links.down_time(self.down_payload)
                        + self.links.up_time(self.up_payload))
        self._hop_s = self._agg_hop_s()
        self._overlay = consensus_nbytes(fl, self.state_nbytes) \
            + self._ledger_nbytes
        if fl.topology == "hierarchical":
            self._overlay += hierarchical_nbytes(
                0, 0, self.state_nbytes, self.pods)[1]
        self._gossip_step_s = (
            self._gossip_per_client / self.links.up_Bps
            + self._gossip_per_client / self.links.down_Bps
            + 2.0 * self.links.latency_s)

    # -- participation replay ---------------------------------------------
    def _kept(self, r: int) -> np.ndarray:
        """(C,) bool: the round's kept cohort, bitwise the in-program mask
        (``rounds.build_multi_round`` seeds the fault with the lane's swept
        seed — ``self.csm`` is already built per lane the same way)."""
        C = int(self.fl.n_clients)
        if self._trivial_mask:
            return np.ones(C, bool)
        from repro.runtime.faults import cohort_mask
        m = np.asarray(cohort_mask(self.csm, r, C, self._target,
                                   self.fl.straggler_overprovision))
        return m > 0

    def _agg_hop_s(self) -> float:
        """Extra aggregation-hop latency past the plain server reduce: one
        per backbone tier (hierarchical) and one per consensus exchange.
        Zero for single-worker client_server — which is what lets the sync
        makespan agree exactly with the shifted async vtime on the
        FedAvg-identity configuration."""
        hop = 0.0
        if self.fl.topology == "hierarchical":
            hop += self.links.latency_s
        if max(int(self.fl.n_workers), 1) > 1:
            hop += self.links.latency_s
        return hop

    # -- sync rounds -------------------------------------------------------
    def sync_rounds(self, start: int, n: int) -> dict:
        """Account rounds [start, start+n): per-round byte totals and the
        simulated makespan, plus the running cumulative columns."""
        fl = self.fl
        C = int(fl.n_clients)
        out = {k: np.zeros(n, np.float64) for k in COMMS_COLUMNS}
        for i in range(n):
            r = start + i
            dur = _dur_column(self.csm, self.rate, r).astype(np.float64)
            if fl.topology == "decentralized":
                # no server: every client gossips regardless of the weight
                # mask (the mix ignores aggregation weights)
                up = C * self._gossip_per_client
                down = up                      # each send is a receive
                dense_up = up
                overlay = self._ledger_nbytes
                makespan = float((dur + self._gossip_step_s).max())
            elif self._trivial_mask:
                up = C * self.up_payload
                down = C * self.down_payload
                dense_up = C * self.state_nbytes
                overlay = self._overlay
                makespan = float((dur + self._t_link).max()) + self._hop_s
            else:
                kept = self._kept(r)
                k = int(kept.sum())
                up = k * self.up_payload
                down = k * self.down_payload
                dense_up = k * self.state_nbytes
                overlay = self._overlay
                if k:
                    t_c = dur + self._t_link
                    makespan = float(t_c[kept].max()) + self._hop_s
                else:
                    makespan = 0.0
            self._advance(out, i, up, down, overlay, dense_up,
                          self.sim_time + makespan)
        return out

    # -- async event windows ----------------------------------------------
    def async_rounds(self, start: int, n: int, schedule,
                     events_per_round: int) -> dict:
        """Account async "rounds" (fixed event windows): downlink per
        dispatched task, uplink only for *accepted* arrivals (a rejected
        arrival's bytes never reach the aggregation path — the zero-uplink
        invariant), simulated time = ``vtime`` shifted by each client's
        cumulative transfer time, folded monotone by a running max."""
        epr = int(events_per_round)
        e0 = start * epr
        out = {k: np.zeros(n, np.float64) for k in COMMS_COLUMNS}
        cli = np.asarray(schedule.client[e0:e0 + n * epr])
        task = np.asarray(schedule.task[e0:e0 + n * epr], np.float64)
        acc = np.asarray(schedule.accept[e0:e0 + n * epr], bool)
        vt = np.asarray(schedule.vtime[e0:e0 + n * epr], np.float64)
        up_t = self.links.up_time(self.up_payload)      # (C,)
        down_t = self.links.down_time(self.down_payload)
        w = vt + (task + 1.0) * (up_t[cli] + down_t[cli])
        for i in range(n):
            sl = slice(i * epr, (i + 1) * epr)
            up = int(acc[sl].sum()) * self.up_payload
            dense_up = int(acc[sl].sum()) * self.state_nbytes
            down = epr * self.down_payload
            t = max(self.sim_time, float(w[sl].max()))
            self._advance(out, i, up, down, 0, dense_up, t)
        return out

    def frozen(self, n: int) -> dict:
        """A dead/padded lane's columns: zero per-round traffic, cumulative
        counters held at their freeze values."""
        out = {k: np.zeros(n, np.float64) for k in COMMS_COLUMNS}
        out["cum_up_bytes"][:] = self.cum_up
        out["cum_down_bytes"][:] = self.cum_down
        out["cum_bytes"][:] = self.cum_up + self.cum_down + self.cum_overlay
        out["sim_time_s"][:] = self.sim_time
        return out

    def _advance(self, out: dict, i: int, up: int, down: int, overlay: int,
                 dense_up: int, sim_time: float):
        self.cum_up += int(up)
        self.cum_down += int(down)
        self.cum_overlay += int(overlay)
        self.cum_dense_up += int(dense_up)
        makespan = sim_time - self.sim_time
        self.sim_time = float(sim_time)
        out["up_bytes"][i] = up
        out["down_bytes"][i] = down
        out["overlay_bytes"][i] = overlay
        out["makespan_s"][i] = makespan
        out["cum_up_bytes"][i] = self.cum_up
        out["cum_down_bytes"][i] = self.cum_down
        out["cum_bytes"][i] = self.cum_up + self.cum_down + self.cum_overlay
        out["sim_time_s"][i] = self.sim_time

    def summary(self) -> dict:
        """Run-level totals for the ``comms_total`` counter / trace report:
        cumulative per-direction bytes, the dense-equivalent uplink (the
        compression-ratio denominator), and the simulated wall-clock."""
        return {"up_bytes": int(self.cum_up),
                "down_bytes": int(self.cum_down),
                "overlay_bytes": int(self.cum_overlay),
                "dense_up_bytes": int(self.cum_dense_up),
                "sim_time_s": float(self.sim_time)}
