"""Sweep grid expansion for campaigns (FLsim's "plethora of experiments"
claim, rendered as one compiled program).

A job config plus a ``sweep:`` section expands into S trajectories — the
row-major product of the sweep axes. The axes split into three planes, which
is what lets all S trajectories share ONE ``jax.vmap``-over-the-scan launch:

- **data plane** (``seed``, ``dirichlet_alpha``): the value changes the root
  dataset and/or the client partitions, so each trajectory restages; the
  staged tensors stack to a leading (S,) dim
  (``data/pipeline.stage_partitions_stacked``).
- **schedule plane** (``staleness_exponent``): async only — the value
  reshapes the host-precomputed event schedule (coefficients). Schedules
  dedup the way data roots do: lanes sharing (seed, partition, alpha,
  staleness_exponent) share ONE (E,) schedule on device, and a per-lane
  index maps lanes to the U unique rows; the compiled event scan is
  unchanged.
- **scalar plane** (``client_lr``, ``prox_mu``, ``server_lr``, ...): the
  value is threaded into the compiled round/event program as a *traced*
  per-trajectory scalar (``core/rounds.bind_hyper``), so one program serves
  every value — no recompilation across the grid.

``seed`` lives in both the data plane (it reseeds the dataset, partitions
and virtual clock) and the scalar plane (the in-program cohort draw folds it
in), which is why it also appears in ``configs.base.SWEEPABLE_SCALARS``.

A fourth plane exists for *categorical* axes (``strategy``, ``topology``,
``placement``, ``mode``, ``async_buffer``): those values change the traced
program itself, so they cannot share one vmap. ``parse_sweep`` accepts and
validates them here; executing a heterogeneous grid is the campaign
planner's job (``core/plan.py`` buckets trajectories by program signature,
``runtime/scheduler.py::PlanExecutor`` runs one vmapped launch per bucket).

Determinism contract: expansion is pure bookkeeping — trajectory ``s`` of a
campaign is *bitwise identical* to a single run of the s-th expanded config
(tests/test_sweeps.py), because threefry draws are vectorization-invariant
and the scalar plane only swaps Python floats for equal-valued traced f32s.
"""
from __future__ import annotations

import dataclasses
import difflib
import itertools
from typing import Any, Dict, List, Optional, Tuple

import jax.numpy as jnp

from repro.configs.base import (SWEEPABLE_CATEGORICAL, SWEEPABLE_SCALARS,
                                FLConfig)
from repro.core import determinism

DATA_AXES = ("seed", "dirichlet_alpha")
SCHEDULE_AXES = ("staleness_exponent",)
SCALAR_AXES = tuple(k for k in SWEEPABLE_SCALARS if k != "seed")
CATEGORICAL_AXES = SWEEPABLE_CATEGORICAL
# cohort plane: population/cohort sizes are host-side slab-plan values under
# the ragged client plane (fl.max_cohort > 0), so lanes sweeping them share
# one compiled program; with max_cohort == 0 they change the trace and
# bucket through the planner like categorical axes
COHORT_AXES = ("n_clients", "cohort")
KNOWN_AXES = (DATA_AXES + SCHEDULE_AXES + SCALAR_AXES + COHORT_AXES
              + CATEGORICAL_AXES)

# job-YAML convenience: `sweep: {seeds: [0, 1, 2]}`
_AXIS_ALIASES = {"seeds": "seed"}

# legal values per categorical axis; ``None`` -> resolved lazily from the
# live registry (so new strategies are sweepable without touching this)
_CATEGORICAL_CHOICES = {
    "strategy": None,
    "topology": ("client_server", "hierarchical", "decentralized"),
    "placement": ("spatial", "temporal", "auto"),
    "mode": ("sync", "async"),
    "async_buffer": None,            # any int >= 0
    "compression": ("none", "int8", "topk"),
}


def _categorical_values(name, values) -> Tuple[Any, ...]:
    """Validate one categorical axis' values (did-you-mean on typos)."""
    if name == "async_buffer":
        return tuple(int(v) for v in values)
    if name == "strategy":
        from repro.core.strategies import REGISTRY
        choices = tuple(sorted(REGISTRY))
    else:
        choices = _CATEGORICAL_CHOICES[name]
    out = []
    for v in values:
        if v not in choices:
            hint = difflib.get_close_matches(str(v), choices, n=1)
            suffix = (f" — did you mean {hint[0]!r}?" if hint
                      else f"; known values: {list(choices)}")
            raise KeyError(
                f"unknown {name} value {v!r} in sweep axis{suffix}")
        out.append(str(v))
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """Ordered sweep axes; the grid is their row-major product."""
    axes: Tuple[Tuple[str, Tuple[Any, ...]], ...]

    @property
    def names(self) -> Tuple[str, ...]:
        """Sweep axis names in declaration order."""
        return tuple(n for n, _ in self.axes)

    @property
    def size(self) -> int:
        """Number of grid points (product of axis lengths)."""
        s = 1
        for _, vals in self.axes:
            s *= len(vals)
        return s

    def coords(self) -> List[Dict[str, Any]]:
        """One {axis: value} dict per trajectory, row-major (the last axis
        varies fastest) — the key order of the results table."""
        if not self.axes:
            return [{}]
        return [dict(zip(self.names, combo))
                for combo in itertools.product(*(v for _, v in self.axes))]

    @property
    def categorical_names(self) -> Tuple[str, ...]:
        """The swept axes whose values change the compiled program."""
        return tuple(n for n in self.names if n in CATEGORICAL_AXES)


def parse_sweep(section) -> Optional[SweepSpec]:
    """Validate a job's ``sweep:`` section into a SweepSpec (None if absent).

    Unknown axis names fail loudly with a near-miss suggestion — the same
    no-silent-typos contract ``load_job`` applies to its other sections.
    """
    if section is None:
        return None
    if not isinstance(section, dict) or not section:
        raise ValueError("sweep: section must be a non-empty mapping of "
                         f"axis -> list of values; got {section!r}")
    axes = []
    for raw_name, values in section.items():
        name = _AXIS_ALIASES.get(raw_name, raw_name)
        if name not in KNOWN_AXES:
            hint = difflib.get_close_matches(
                name, KNOWN_AXES + tuple(_AXIS_ALIASES), n=1)
            suffix = (f" — did you mean {hint[0]!r}?" if hint
                      else f"; sweepable axes: {sorted(KNOWN_AXES)}")
            raise KeyError(f"unknown sweep axis {raw_name!r}{suffix}")
        if any(name == n for n, _ in axes):
            raise ValueError(f"sweep axis {raw_name!r} duplicates "
                             f"{name!r} (aliases resolve to one axis)")
        if not isinstance(values, (list, tuple)) or len(values) == 0:
            raise ValueError(f"sweep axis {raw_name!r} needs a non-empty "
                             f"list of values; got {values!r}")
        if name in CATEGORICAL_AXES:
            values = _categorical_values(name, values)
        elif name == "seed" or name in COHORT_AXES:
            values = tuple(int(v) for v in values)
        else:
            values = tuple(float(v) for v in values)
        if len(set(values)) != len(values):
            raise ValueError(f"sweep axis {raw_name!r} repeats values "
                             f"{values!r}; the grid would duplicate lanes")
        axes.append((name, tuple(values)))
    return SweepSpec(axes=tuple(axes))


def expand(fl: FLConfig, spec: SweepSpec) -> List[FLConfig]:
    """The S per-trajectory configs, in the grid's row-major order."""
    return [dataclasses.replace(fl, **coord) for coord in spec.coords()]


def scalar_plane(fls: List[FLConfig]) -> Dict[str, Any]:
    """The traced hyper dict: one (S,) array per SWEEPABLE scalar — swept
    axes vary per lane, unswept ones broadcast the base value.

    Every sweepable scalar is included (not just the swept ones) to mirror
    ``runtime.executor.Executor``'s single-run hyper exactly: XLA compiles
    a scalar-multiply chain differently for a compile-time constant than
    for a runtime value, so bitwise campaign==single requires both sides to
    consume the *same* scalars as runtime values.
    """
    hyper = {"seed": jnp.asarray([fl.seed for fl in fls], jnp.int32)}
    for name in SCALAR_AXES:
        hyper[name] = jnp.asarray([getattr(fl, name) for fl in fls],
                                  jnp.float32)
    return hyper


def root_keys(fls: List[FLConfig]):
    """(S, 2) stacked per-trajectory root keys (vmap lane s == the single
    run's ``determinism.root_key(seed_s)``)."""
    return jnp.stack([determinism.root_key(fl.seed) for fl in fls])
