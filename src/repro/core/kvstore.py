"""Key-value store / pub-sub broker (paper component 5).

On the paper's CPU cluster this brokers parameter exchange between node
processes. On the TPU mesh parameter movement is compiled collectives — but
the host-level orchestration (launch/train.py) still needs a broker for
*control-plane* state: round metadata, node stages (Alg. 1), straggler
deadlines, checkpoint manifests. This in-process implementation keeps the
same publish/subscribe surface a distributed deployment (e.g. Redis) would.
"""
from __future__ import annotations

import collections
import threading
from typing import Any, Callable


class KVStore:
    """Thread-safe in-process key-value store with pub-sub callbacks."""
    def __init__(self):
        self._data: dict[str, Any] = {}
        self._subs: dict[str, list[Callable]] = collections.defaultdict(list)
        self._lock = threading.Lock()

    def publish(self, key: str, value: Any) -> None:
        """Set ``key`` and invoke its subscribers outside the lock."""
        with self._lock:
            self._data[key] = value
            subs = list(self._subs.get(key, ()))
        for fn in subs:
            fn(key, value)

    def get(self, key: str, default=None) -> Any:
        """Read ``key``, returning ``default`` when absent."""
        with self._lock:
            return self._data.get(key, default)

    def subscribe(self, key: str, fn: Callable) -> None:
        """Register ``fn(key, value)`` to run on every publish of ``key``."""
        with self._lock:
            self._subs[key].append(fn)

    def keys(self, prefix: str = "") -> list:
        """List stored keys, optionally filtered by ``prefix``."""
        with self._lock:
            return [k for k in self._data if k.startswith(prefix)]

    # -- Alg. 1 signal helpers -----------------------------------------
    def set_process_phase(self, phase: int) -> None:
        """Publish the global Alg. 1 process phase."""
        self.publish("process_phase", phase)

    def set_node_stage(self, node: str, stage: int) -> None:
        """Publish one node's Alg. 1 stage."""
        self.publish(f"node_stage/{node}", stage)

    def all_nodes_in_stage(self, nodes, stage: int) -> bool:
        """True when every listed node has reached ``stage``."""
        return all(self.get(f"node_stage/{n}") == stage for n in nodes)
