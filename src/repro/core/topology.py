"""Network topologies as mesh-axis reduction plans (paper Fig. 4 / RQ5).

- client-server: one weighted mean over the client grid.
- hierarchical: two-tier reduction — intra-pod mean (edge aggregator) then
  cross-pod mean (cloud). On the production mesh the ``pod`` axis IS the
  hierarchy; single-pod runs emulate tiers with (data -> model) stages.
- decentralized: no global reduction — torus gossip via ppermute rings over
  the client grid (doubly stochastic mixing), Fedstellar-style.

All plans also run meshless over a leading client dim (vmap path for the
paper-scale CPU benches).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding.axes import AxisCtx


def _wmean_local(deltas, weights):
    """deltas: (C, ...) leading client dim; weights: (C,)."""
    wsum = weights.sum()
    return jax.tree.map(
        lambda d: jnp.tensordot(weights, d.astype(jnp.float32), axes=1)
        / jnp.maximum(wsum, 1e-12), deltas)


@dataclasses.dataclass(frozen=True)
class ClientServer:
    """Star topology: weighted mean of client deltas at the server."""
    name: str = "client_server"

    def aggregate(self, ctx: AxisCtx, deltas, weights):
        """deltas: (C_loc, ...) per-chip clients; weighted psum over the grid."""
        num = jax.tree.map(
            lambda d: jnp.tensordot(weights, d.astype(jnp.float32), axes=1),
            deltas)
        den = weights.sum()
        axes = tuple(a for a in (ctx.pod, ctx.data, ctx.model) if a)
        if axes:
            num = jax.tree.map(lambda t: jax.lax.psum(t, axes), num)
            den = jax.lax.psum(den, axes)
        return jax.tree.map(lambda t: t / jnp.maximum(den, 1e-12), num)


@dataclasses.dataclass(frozen=True)
class Hierarchical:
    """Edge aggregators first (within pod: data+model axes), then cloud (pod).
    Matches [26]-style hierarchical FL; with cluster weighting the edge tiers
    can aggregate heterogeneous cohort sizes without bias."""
    name: str = "hierarchical"

    def aggregate(self, ctx: AxisCtx, deltas, weights):
        """Two-tier aggregation: pod-local means, then the cross-pod mean."""
        num = jax.tree.map(
            lambda d: jnp.tensordot(weights, d.astype(jnp.float32), axes=1),
            deltas)
        den = weights.sum()
        intra = tuple(a for a in (ctx.data, ctx.model) if a)
        if intra:  # edge tier
            num = jax.tree.map(lambda t: jax.lax.psum(t, intra), num)
            den = jax.lax.psum(den, intra)
        edge = jax.tree.map(lambda t: t / jnp.maximum(den, 1e-12), num)
        if ctx.pod:  # cloud tier over pod aggregates
            edge = jax.tree.map(lambda t: jax.lax.pmean(t, ctx.pod), edge)
        return edge


@dataclasses.dataclass(frozen=True)
class Decentralized:
    """k steps of torus gossip; returns per-client mixed deltas (no global)."""
    name: str = "decentralized"
    gossip_steps: int = 1

    def mix(self, ctx: AxisCtx, state):
        """state: per-client pytree (C_loc leading dim). One gossip step mixes
        each client with its ring neighbours along both grid axes."""
        def step(t):
            mixed = t.astype(jnp.float32)
            n = 1
            for axis in (ctx.model, ctx.data):
                if axis is not None:
                    sz = ctx.size(axis)
                    right = jax.lax.ppermute(
                        mixed, axis, [(i, (i + 1) % sz) for i in range(sz)])
                    left = jax.lax.ppermute(
                        mixed, axis, [(i, (i - 1) % sz) for i in range(sz)])
                    mixed = mixed + right + left
                    n += 2
            if ctx.model is None and ctx.data is None and t.shape[0] > 1:
                # roll the f32-cast accumulator, not the raw t: the meshless
                # ring must feed the same dtype into the accumulator as the
                # ppermute path (which exchanges the cast ``mixed``)
                mixed = mixed + jnp.roll(mixed, 1, 0) + jnp.roll(mixed, -1, 0)
                n += 2
            return (mixed / n).astype(t.dtype)

        for _ in range(self.gossip_steps):
            state = jax.tree.map(step, state)
        return state

    def aggregate(self, ctx: AxisCtx, deltas, weights):
        """Gossip-average deltas over the ring for ``gossip_steps``."""
        return self.mix(ctx, deltas)


# neighbours each client exchanges with per gossip step (the meshless ring
# rolls ±1; the comms byte model in core/netmodel.py counts sends off it)
GOSSIP_NEIGHBORS = 2

_TOPOLOGIES = ("client_server", "hierarchical", "decentralized")


def get_topology(name: str, gossip_steps: int = 1):
    """Resolve a topology implementation by name."""
    if name == "client_server":
        return ClientServer()
    if name == "hierarchical":
        return Hierarchical()
    if name == "decentralized":
        return Decentralized(gossip_steps=gossip_steps)
    import difflib
    hint = difflib.get_close_matches(name, _TOPOLOGIES, n=1)
    suffix = (f" — did you mean {hint[0]!r}?" if hint
              else f"; known topologies: {list(_TOPOLOGIES)}")
    raise ValueError(f"unknown topology {name!r}{suffix}")
