"""FL rounds as single compiled programs (the TPU rendering of Alg. 1).

Two client placements (DESIGN.md):

- ``spatial``  — each point of the flattened client grid (data x model [x pod])
  hosts one or more whole clients; local epochs run truly in parallel under
  shard_map (vmap over the per-chip client dim), aggregation is a weighted
  psum / gossip ppermute per the topology. The model itself runs *unsharded*
  inside each client (AxisCtx() is passed down).

- ``temporal`` — one client at a time uses the entire mesh (ZeRO-3 + SP
  sharding from sharding/specs.py); the cohort is a lax.scan, deltas are
  accumulated with client weights, then the server update runs. With
  cohort=1 and E=1 a round is mathematically one data-parallel step +
  server optimizer — that identity is a unit test.

Both paths run meshless (AxisCtx()) for CPU-scale tests and benches.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SWEEPABLE_SCALARS, FLConfig, ModelConfig
from repro.core import determinism, packing
from repro.core import probes as probelib
from repro.core.consensus import MultiWorkerAggregator
from repro.core.strategy import (Strategy, client_sgd_step, tree_add,
                                 tree_scale, tree_sub, tree_zeros_like)
from repro.core.topology import Decentralized, get_topology
from repro.sharding.axes import AxisCtx

PyTree = Any


def bind_hyper(fl: FLConfig, strategy: Strategy, hyper):
    """Rebind swept scalars (possibly traced) onto the (fl, strategy) pair.

    ``hyper`` is a dict mapping SWEEPABLE_SCALARS names to scalars — Python
    floats or traced 0-d arrays (one vmap lane of a campaign's (S,) sweep
    axis). With ``hyper`` empty/None this is the identity, so the
    single-trajectory path is untouched."""
    if not hyper:
        return fl, strategy
    unknown = set(hyper) - set(SWEEPABLE_SCALARS)
    if unknown:
        raise KeyError(f"non-sweepable hyper keys {sorted(unknown)}; "
                       f"sweepable scalars: {SWEEPABLE_SCALARS}")
    fl_h = dataclasses.replace(fl, **hyper)
    return fl_h, dataclasses.replace(strategy, fl=fl_h)


def pop_alive(hyper):
    """Split the lane-scheduler's alive mask off a hyper dict.

    ``alive`` is the one hyper entry that is not a SWEEPABLE scalar: a
    per-lane 0/1 float the campaign threads as a *runtime* value so the
    lane scheduler (runtime/scheduler.py) can zero-weight dropped lanes
    between chunk launches without recompiling. Returns ``(alive, rest)``
    with ``alive`` None when absent (every single-run path)."""
    if not hyper or "alive" not in hyper:
        return None, hyper
    rest = dict(hyper)
    return rest.pop("alive"), rest


def freeze_unless(alive, new_state, old_state):
    """Select ``new_state`` where ``alive`` > 0, else keep ``old_state``.

    A dropped lane's state freezes at its drop round: the select picks
    whole computed tensors, so for alive lanes it is bitwise the identity
    (the load-bearing property for the scheduler-off contract)."""
    keep = alive > 0
    return jax.tree.map(lambda n, o: jnp.where(keep, n, o),
                        new_state, old_state)


# ---------------------------------------------------------------------------
# Per-client local training (pure; no cross-client communication)
# ---------------------------------------------------------------------------

def local_train(model, model_ctx: AxisCtx, strategy: Strategy, fl: FLConfig,
                global_params, server_state, client_state, batches, rng,
                gather_fn=lambda b: b, grad_sync=lambda g: g,
                pack_deltas: bool = False):
    """Run E local epochs over ``batches`` (leading dim = steps).

    Returns (delta, new_client_state, mean_loss). With ``pack_deltas`` the
    delta leaves the client as a ``packing.PackedDelta`` (int8 + block
    scales, via ``Strategy.postprocess_packed``) — what actually crosses the
    simulated network on the compressed path."""
    post = strategy.postprocess_packed if pack_deltas else strategy.postprocess
    n_steps = jax.tree.leaves(batches)[0].shape[0]
    use_mom = fl.client_optimizer == "sgdm" and fl.client_momentum > 0
    mom0 = tree_zeros_like(global_params) if use_mom else None

    def base_loss(p, b, key):
        return model.loss(model_ctx, p, b, gather_fn)

    if fl.local_epochs * n_steps == 1 and not use_mom:
        # Fast path: one local SGD step => delta == -lr * grad. Elides the
        # params' copy + subtraction buffers (matters at 400B scale).
        batch = jax.tree.map(lambda t: t[0], batches)
        key = determinism.step_key(rng, 0)

        def lfn(p):
            return strategy.local_loss(base_loss, p, global_params, batch,
                                       client_state, key)

        (loss, _), grads = jax.value_and_grad(lfn, has_aux=True)(global_params)
        grads = grad_sync(grads)
        grads = strategy.grad_transform(grads, client_state, server_state)
        delta = jax.tree.map(
            lambda p, g: (-fl.client_lr * g).astype(p.dtype),
            global_params, grads)
        delta, client_state = post(delta, client_state, rng)
        client_state = strategy.client_state_update(
            client_state, server_state, delta, 1, fl.client_lr)
        return delta, client_state, loss

    def one_step(carry, xs):
        params, mom = carry
        step_idx, key = xs
        batch = jax.tree.map(lambda t: t[step_idx % n_steps], batches)

        def lfn(p):
            return strategy.local_loss(base_loss, p, global_params, batch,
                                       client_state, key)

        (loss, _), grads = jax.value_and_grad(lfn, has_aux=True)(params)
        grads = grad_sync(grads)
        grads = strategy.grad_transform(grads, client_state, server_state)
        params, new_mom = client_sgd_step(params, grads, fl.client_lr, mom,
                                          fl.client_momentum)
        return (params, new_mom), loss

    total = fl.local_epochs * n_steps
    keys = jax.vmap(lambda i: determinism.step_key(rng, i))(jnp.arange(total))
    (params, _), losses = jax.lax.scan(
        one_step, (global_params, mom0), (jnp.arange(total), keys))
    delta = tree_sub(params, global_params)
    delta, client_state = post(delta, client_state, rng)
    client_state = strategy.client_state_update(
        client_state, server_state, delta, total, fl.client_lr)
    return delta, client_state, losses.mean()


# ---------------------------------------------------------------------------
# Packed (int8) server-side aggregation
# ---------------------------------------------------------------------------

def packed_aggregate(topo, ctx: AxisCtx, pd, weights):
    """Weighted mean of stacked ``PackedDelta``s ((C, N) int8 + (C, N/b)
    scales) through the fused dequant+weighted-sum kernel, following the
    topology's reduction plan: each int8 byte is read once and only the
    (N,) f32 numerator crosses the mesh. Returns the flat f32 aggregate."""
    from repro.kernels import ops
    from repro.core.topology import Hierarchical
    num = ops.quant_aggregate(pd.q, pd.scale, weights)
    den = weights.sum()
    if isinstance(topo, Hierarchical):
        intra = tuple(a for a in (ctx.data, ctx.model) if a)
        if intra:      # edge tier
            num = jax.lax.psum(num, intra)
            den = jax.lax.psum(den, intra)
        agg = num / jnp.maximum(den, 1e-12)
        if ctx.pod:    # cloud tier
            agg = jax.lax.pmean(agg, ctx.pod)
        return agg
    axes = tuple(a for a in (ctx.pod, ctx.data, ctx.model) if a)
    if axes:
        num = jax.lax.psum(num, axes)
        den = jax.lax.psum(den, axes)
    return num / jnp.maximum(den, 1e-12)


# ---------------------------------------------------------------------------
# Spatial round
# ---------------------------------------------------------------------------

def build_spatial_round(model, strategy: Strategy, fl: FLConfig,
                        probes: bool = False):
    """Returns round_fn(ctx, state, batch, weights, rng) -> (state, metrics).

    state: {"params", "server", "clients"}; for decentralized topology
    ``params`` carries the per-client leading dim (diverged models).

    ``probes`` (a trace-time flag: off compiles the exact pre-probe program)
    adds a ``metrics["probes"]`` dict of read-only per-round diagnostics
    (core/probes.py) — pure extra consumers of the round's intermediates,
    so probes-on trajectories stay bitwise probes-off."""
    topo = get_topology(fl.topology, fl.gossip_steps)
    decentralized = isinstance(topo, Decentralized)
    mw = (MultiWorkerAggregator(fl.n_workers, fl.byzantine_workers,
                                fl.consensus)
          if (fl.n_workers > 1 or fl.byzantine_workers > 0) else None)
    inner = AxisCtx()   # the model runs unsharded inside each client
    # gossip mixing has no server-side reduce to fuse into — the packed
    # path is the client->server topologies' (ROADMAP: gossip follow-on)
    packed = strategy.packs_deltas and not decentralized

    def round_fn(ctx: AxisCtx, state, batch, weights, rng, hyper=None):
        """batch: (C_loc, steps, B_c, ...); weights: (C_loc,)."""
        fl_h, strategy_h = bind_hyper(fl, strategy, hyper)
        params = state["params"]
        server_state = state["server"]
        C_loc = jax.tree.leaves(batch)[0].shape[0]
        chip = ctx.index(ctx.model)
        for axis in (ctx.data, ctx.pod):
            if axis is not None:
                chip = chip * 0 + ctx.index(axis) * _grid_below(ctx, axis) + chip
        client_ids = chip * C_loc + jnp.arange(C_loc)
        keys = jax.vmap(lambda c: determinism.client_key(rng, c))(client_ids)
        axes = tuple(a for a in (ctx.pod, ctx.data, ctx.model) if a)
        psum_ = (lambda x: jax.lax.psum(x, axes)) if axes else (lambda x: x)
        pmean_ = (lambda x: jax.lax.pmean(x, axes)) if axes else (lambda x: x)
        pr = {}

        def per_client(cbatch, cstate, key, start_params):
            delta, cst, loss = local_train(
                model, inner, strategy_h, fl_h, start_params, server_state,
                cstate, cbatch, key, pack_deltas=packed)
            if not probes or decentralized:
                return delta, cst, loss
            # probe moments computed where the delta/residual are written
            # (cache-hot, fusable with the producing ops) — a separate
            # post-vmap pass would re-read every client's full parameter
            # volume at memory speed, which dwarfs the training compute
            # on small models
            ex = {"sq": (probelib.packed_sq_norm(delta.q, delta.scale)
                         if packed else probelib.tree_sq_norm(delta))}
            if packed:
                ex["sat"] = probelib.sat_frac(delta.q)
            if isinstance(cst, dict) and "residual" in cst:
                ex["rsq"] = probelib.tree_sq_norm(cst["residual"])
            return delta, cst, loss, ex

        if decentralized:
            deltas, cstates, losses = jax.vmap(per_client)(
                batch, state["clients"], keys, params)
            updated = tree_add(params, deltas)
            mixed = topo.mix(ctx, updated)
            new_params = mixed
            new_server = server_state
            if probes:
                # drift for gossip = param spread across the client models:
                # sqrt(mean_c ||p_c - mean_c' p_c'||^2)
                mean_p = jax.tree.map(lambda t: pmean_(t.mean(0)), new_params)
                spread = probelib.per_client_sq_norms(jax.tree.map(
                    lambda t, m: t - m[None], new_params, mean_p))
                pr["drift_norm"] = jnp.sqrt(pmean_(spread.mean()))
                pr["sat_frac"] = jnp.zeros((), jnp.float32)
                pr["ef_residual_norm"] = jnp.zeros((), jnp.float32)
        else:
            out = jax.vmap(per_client, in_axes=(0, 0, 0, None))(
                batch, state["clients"], keys, params)
            if probes:
                deltas, cstates, losses, pex = out
            else:
                deltas, cstates, losses = out
            if packed:
                agg_flat = packed_aggregate(topo, ctx, deltas, weights)
                agg = packing.unpack_tree(agg_flat, params)
            else:
                agg = topo.aggregate(ctx, deltas, weights)
            if mw is not None:
                agg = mw.run(agg, rng)
            agg = jax.tree.map(lambda a, p: a.astype(p.dtype), agg, params)
            new_params, new_server = strategy_h.server_update(
                params, agg, server_state)
            # SCAFFOLD: the server control variate is the cohort mean of the
            # client variates (communicated alongside the deltas, per the
            # paper's "additional states" requirement (5)).
            if isinstance(new_server, dict) and "c" in new_server \
                    and isinstance(cstates, dict) and "c_i" in cstates:
                new_server = dict(new_server,
                                  c=topo.aggregate(ctx, cstates["c_i"],
                                                   weights))
            if probes:
                pr["sat_frac"] = (pmean_(pex["sat"].mean()) if packed
                                  else jnp.zeros((), jnp.float32))
                pr["drift_norm"] = probelib.drift_from_moments(
                    weights, pex["sq"], probelib.tree_sq_norm(agg), psum_)
                if "rsq" in pex:
                    pr["ef_residual_norm"] = jnp.sqrt(
                        psum_(pex["rsq"].sum()) / jnp.maximum(
                            psum_(jnp.asarray(C_loc, jnp.float32)), 1.0))
                else:
                    pr["ef_residual_norm"] = jnp.zeros((), jnp.float32)
        loss = losses.mean()
        if axes:
            loss = jax.lax.pmean(loss, axes)
        new_state = {"params": new_params, "server": new_server,
                     "clients": cstates}
        metrics = {"loss": loss}
        if probes:
            pr["update_norm"] = probelib.tree_norm(
                tree_sub(new_params, params))
            pr["nonfinite"] = probelib.norm_nonfinite(pr["update_norm"])
            metrics["probes"] = pr
        return new_state, metrics

    return round_fn


def _grid_below(ctx: AxisCtx, axis: str) -> int:
    """Flattened grid stride for client-id computation."""
    if axis == ctx.data:
        return ctx.size(ctx.model)
    if axis == ctx.pod:
        return ctx.size(ctx.model) * ctx.size(ctx.data)
    return 1


# ---------------------------------------------------------------------------
# Temporal round
# ---------------------------------------------------------------------------

def build_temporal_round(model, strategy: Strategy, fl: FLConfig,
                         cfg: ModelConfig, probes: bool = False):
    """Returns round_fn(ctx, state, batch, weights, rng) -> (state, metrics).

    batch: (C_t, steps, B_loc, ...) — cohort clients scanned in time, each
    using the whole mesh. For C_t == 1 the delta buffer is elided.
    ``probes`` as in ``build_spatial_round`` (for the scanned-client path
    the drift moments accumulate in the fori carry — only weighted sums are
    needed, never the stacked deltas)."""
    from repro.sharding import specs as sspecs
    topo = get_topology(fl.topology, fl.gossip_steps)
    mw = (MultiWorkerAggregator(fl.n_workers, fl.byzantine_workers,
                                fl.consensus)
          if (fl.n_workers > 1 or fl.byzantine_workers > 0) else None)
    packed = strategy.packs_deltas

    def round_fn(ctx: AxisCtx, state, batch, weights, rng, hyper=None):
        fl_h, strategy_h = bind_hyper(fl, strategy, hyper)
        params = state["params"]
        server_state = state["server"]
        gather_fn = sspecs.make_gather_fn(cfg, ctx)
        grad_sync = sspecs.make_grad_sync(cfg, ctx)
        C_t = jax.tree.leaves(batch)[0].shape[0]

        def client(i, carry):
            acc, loss_acc, *rest = carry
            cbatch = jax.tree.map(lambda t: t[i], batch)
            key = determinism.client_key(rng, i)
            delta, _, loss = local_train(
                model, ctx, strategy_h, fl_h, params, server_state, (),
                cbatch, key, gather_fn, grad_sync)
            w = weights[i]
            acc = tree_add(acc, tree_scale(
                delta, w / jnp.maximum(weights.sum(), 1e-12)))
            out = (acc, loss_acc + loss / C_t)
            if probes:
                # weighted second moment of the deltas for the drift probe
                out += (rest[0] + w / jnp.maximum(weights.sum(), 1e-12)
                        * probelib.tree_sq_norm(delta),)
            return out

        def client_packed(i):
            cbatch = jax.tree.map(lambda t: t[i], batch)
            key = determinism.client_key(rng, i)
            pd, _, loss = local_train(
                model, ctx, strategy_h, fl_h, params, server_state, (),
                cbatch, key, gather_fn, grad_sync, pack_deltas=True)
            return pd, loss

        pr = {"sat_frac": jnp.zeros((), jnp.float32),
              "ef_residual_norm": jnp.zeros((), jnp.float32),
              "drift_norm": jnp.zeros((), jnp.float32)} if probes else {}
        if packed:
            # clients still run one at a time (lax.map scans), but their
            # int8 sends are stacked to the kernel's (C_t, N) layout and
            # reduced in ONE fused dequant+weighted-sum
            if C_t == 1:
                pd, loss = client_packed(0)
                pds = jax.tree.map(lambda t: t[None], pd)
                w = jnp.ones((1,), jnp.float32)   # C_t==1 applies raw delta
            else:
                pds, losses = jax.lax.map(client_packed, jnp.arange(C_t))
                loss = losses.sum() / C_t
                w = weights / jnp.maximum(weights.sum(), 1e-12)
            from repro.kernels import ops
            agg_flat = ops.quant_aggregate(pds.q, pds.scale, w)
            agg = jax.tree.map(
                lambda a, p: a.astype(p.dtype),
                packing.unpack_tree(agg_flat, params), params)
            if probes:
                pr["sat_frac"] = probelib.sat_frac(pds.q)
                pr["drift_norm"] = probelib.drift_from_moments(
                    w, probelib.packed_sq_norms(pds.q, pds.scale),
                    jnp.sum(jnp.square(agg_flat)))
        elif C_t == 1:
            cbatch = jax.tree.map(lambda t: t[0], batch)
            key = determinism.client_key(rng, 0)
            agg, _, loss = local_train(
                model, ctx, strategy_h, fl_h, params, server_state, (),
                cbatch, key, gather_fn, grad_sync)
        else:
            acc0 = tree_zeros_like(params)
            if probes:
                agg, loss, msq = jax.lax.fori_loop(
                    0, C_t, lambda i, c: client(i, c),
                    (acc0, 0.0, jnp.zeros((), jnp.float32)))
                # msq is already the weighted mean (weights normalized in
                # the carry), so the variance identity needs no psum here
                pr["drift_norm"] = jnp.sqrt(jnp.maximum(
                    msq - probelib.tree_sq_norm(agg), 0.0))
            else:
                agg, loss = jax.lax.fori_loop(
                    0, C_t, lambda i, c: client(i, c), (acc0, 0.0))

        # hierarchical/cross-pod tier: average edge aggregates over pods
        if ctx.pod is not None:
            agg = jax.tree.map(lambda t: jax.lax.pmean(t, ctx.pod), agg)
        if mw is not None:
            agg = mw.run(agg, rng)
        new_params, new_server = strategy_h.server_update(params, agg,
                                                          server_state)
        new_state = {"params": new_params, "server": new_server,
                     "clients": state.get("clients", ())}
        axes = tuple(a for a in (ctx.pod, ctx.data, ctx.model) if a)
        if axes:
            loss = jax.lax.pmean(loss, axes)
        metrics = {"loss": loss}
        if probes:
            pr["update_norm"] = probelib.tree_norm(
                tree_sub(new_params, params))
            pr["nonfinite"] = probelib.norm_nonfinite(pr["update_norm"])
            if axes:
                # the temporal model is sharded; probe scalars are computed
                # identically per device (grad_sync replicates), so pmean is
                # the replication-safe fold
                pr = {k: jax.lax.pmean(v, axes) for k, v in pr.items()}
            metrics["probes"] = pr
        return new_state, metrics

    return round_fn


# ---------------------------------------------------------------------------
# Device-resident multi-round driver
# ---------------------------------------------------------------------------

def build_multi_round(model, strategy: Strategy, fl: FLConfig, cfg=None,
                      placement: str = "spatial", fault=None,
                      batch_size: Optional[int] = None,
                      probes: bool = False, on_divergence: str = "report"):
    """Fuse ``rounds_per_launch`` FL rounds into one compiled program.

    Wraps a single-round program (spatial or temporal) in a ``jax.lax.scan``
    whose body does, *inside* the compiled program, everything the host loop
    used to do per round:

    - per-round batch gather from the partition tensors staged on device once
      (``data.pipeline.stage_partitions``), indices derived from
      ``determinism.round_key`` so chunking cannot change the data stream;
    - cohort selection with deadline-drop straggler semantics as a weight
      mask (``runtime.faults.cohort_mask``) — dropped clients get zero weight
      with no host round-trip.

    Returns ``multi_fn(ctx, state, staged, root, start_round, n_rounds)``
    -> ``(state, metrics)`` where ``n_rounds`` must be a Python int (it is
    the scan length; jit with it closed over or static) and every metric
    comes back stacked with a leading ``n_rounds`` dim.

    Determinism contract: because each round's randomness is keyed only by
    ``(root, absolute round index)``, a run chunked as e.g. 10+10 rounds is
    bitwise-identical to 20 launches of 1 round (asserted by
    tests/test_driver.py).
    """
    from repro.data.pipeline import gather_client_batches
    from repro.runtime.faults import FaultModel, cohort_mask

    if placement == "temporal":
        if cfg is None:
            raise ValueError("temporal placement needs the ModelConfig "
                             "(sharding specs are derived from it)")
        single = build_temporal_round(model, strategy, fl, cfg, probes=probes)
    elif placement == "spatial":
        single = build_spatial_round(model, strategy, fl, probes=probes)
    else:
        raise ValueError(f"unknown placement {placement!r} "
                         "(want 'spatial' or 'temporal')")
    freeze_div = probes and on_divergence == "freeze"
    fault = fault if fault is not None else FaultModel(seed=fl.seed)
    batch_size = batch_size or fl.batch_size
    steps = max(fl.local_steps, 1)
    target = int(fl.cohort or fl.n_clients)

    def multi_fn(ctx: AxisCtx, state, staged, root, start_round,
                 n_rounds: int, hyper=None):
        alive, hyper = pop_alive(hyper)
        # a swept seed must also steer the in-program cohort draw
        fault_h = (dataclasses.replace(fault, seed=hyper["seed"])
                   if hyper and "seed" in hyper else fault)
        base_w = staged["len"].astype(jnp.float32)

        def body(st, r):
            rkey = determinism.round_key(root, r)
            batch = gather_client_batches(staged, rkey, batch_size, steps)
            mask = cohort_mask(fault_h, r, fl.n_clients, target,
                               fl.straggler_overprovision)
            eff_w = base_w * mask
            new_st, metrics = single(ctx, st, batch, eff_w, rkey, hyper)
            if probes:
                # engine probes live here, where the cohort/straggler mask
                # and the staged weight mass both exist
                pr = metrics.pop("probes")
                pr["participation"] = (eff_w > 0).sum().astype(jnp.float32)
                pr["masked_frac"] = 1.0 - eff_w.sum() / jnp.maximum(
                    base_w.sum(), 1e-12)
                if freeze_div:
                    # hold a diverged lane at its last finite state — the
                    # same runtime select the lane scheduler uses, compiled
                    # in from launch 1 (a divergence never recompiles)
                    new_st = freeze_unless(1.0 - pr["nonfinite"], new_st, st)
            if alive is not None:
                new_st = freeze_unless(alive, new_st, st)
            if probes:
                if alive is not None:
                    pr = probelib.mask_probes(alive, pr)
                # one stacked (P,) vector, not 7 scalars: the scan emits a
                # single (R, P) probe plane per launch (one output buffer,
                # one host transfer), (S, R, P) under the campaign vmap
                metrics = dict(metrics, probes=probelib.stack_probes(pr))
            return new_st, metrics

        rounds = start_round + jnp.arange(n_rounds)
        return jax.lax.scan(body, state, rounds)

    return multi_fn


def check_ragged_support(fl: FLConfig, strategy: Strategy,
                         placement: str = "spatial") -> None:
    """Reject configs the ragged client plane cannot honor.

    Ragged mode trains only the sampled cohort, so anything that keeps
    per-client state across rounds (SCAFFOLD/MOON variates, error-feedback
    residuals) or per-client parameters (decentralized topology) would
    silently skip updates for unsampled clients — refuse loudly instead.
    """
    topo = get_topology(fl.topology, fl.gossip_steps)
    if isinstance(topo, Decentralized):
        raise ValueError(
            "ragged cohorts (max_cohort > 0) need client-anonymous state, "
            "but the decentralized topology keeps per-client parameters — "
            "use a client_server/hierarchical topology or max_cohort: 0")
    if _has_client_state(strategy):
        raise ValueError(
            f"ragged cohorts (max_cohort > 0) cannot carry per-client "
            f"strategy state (strategy {fl.strategy!r}"
            + (", error_feedback" if fl.error_feedback else "")
            + ") — unsampled clients would never update it; use a "
            "stateless strategy or max_cohort: 0")
    if placement != "spatial":
        raise ValueError(
            f"ragged cohorts support the spatial placement only, got "
            f"{placement!r} — the cohort slab is a per-slot client grid")


def build_ragged_multi(model, strategy: Strategy, fl: FLConfig,
                       placement: str = "spatial",
                       batch_size: Optional[int] = None,
                       probes: bool = False, on_divergence: str = "report"):
    """The ragged-cohort rendering of ``build_multi_round``.

    Instead of gathering batches for all ``n_clients`` from a resident
    root, each round of the scan consumes one *cohort slab row* (see
    ``data.pipeline.SlabStager``): the sampled cohort's shards padded to
    K = max_cohort slots with the tail zero-weighted. The population size
    and cohort draw live entirely on the host, so ``n_clients``/``cohort``
    drop out of the program signature — any population trains through one
    compiled program per (K, Lmax, scan length).

    Returns ``multi_fn(ctx, state, slab, root, start_round, n_rounds,
    hyper)`` with the slab in the resident driver's ``staged`` slot (the
    executors launch both through the same call shape). Randomness is keyed
    by (root, absolute round) and, per slot, by the *real* client id the
    slab carries — so chunking and slab pad width are unobservable, and
    streaming vs resident staging is bitwise the same program on the same
    bytes.
    """
    from repro.data.pipeline import gather_slab_batches

    check_ragged_support(fl, strategy, placement)
    single = build_spatial_round(model, strategy, fl, probes=probes)
    freeze_div = probes and on_divergence == "freeze"
    batch_size = batch_size or fl.batch_size
    steps = max(fl.local_steps, 1)
    k_slots = int(fl.max_cohort)

    def multi_fn(ctx: AxisCtx, state, slab, root, start_round,
                 n_rounds: int, hyper=None):
        alive, hyper = pop_alive(hyper)

        def body(st, xs):
            r, row = xs
            rkey = determinism.round_key(root, r)
            batch = gather_slab_batches(row, rkey, batch_size, steps)
            eff_w = row["w"]
            new_st, metrics = single(ctx, st, batch, eff_w, rkey, hyper)
            if probes:
                # participation counts real (non-pad) slots; masked_frac is
                # the pad fraction of the slab — the population weight mass
                # is a host-side quantity in ragged mode
                pr = metrics.pop("probes")
                real = (eff_w > 0).astype(jnp.float32)
                pr["participation"] = real.sum()
                pr["masked_frac"] = 1.0 - real.sum() / k_slots
                if freeze_div:
                    new_st = freeze_unless(1.0 - pr["nonfinite"], new_st, st)
            if alive is not None:
                new_st = freeze_unless(alive, new_st, st)
            if probes:
                if alive is not None:
                    pr = probelib.mask_probes(alive, pr)
                metrics = dict(metrics, probes=probelib.stack_probes(pr))
            return new_st, metrics

        rounds = start_round + jnp.arange(n_rounds)
        return jax.lax.scan(body, state, (rounds, slab))

    return multi_fn


def init_state(model, strategy: Strategy, fl: FLConfig, key,
               n_clients_local: int = 1, dtype=jnp.float32,
               decentralized: bool = False):
    """Initial FL state (meshless path; sharded init goes via launch/)."""
    params = model.init(key, dtype)
    cstate = ()
    if _has_client_state(strategy):
        # probe the client state off the params we already initialized —
        # a second model.init here would double the init cost at scale
        cstate = jax.tree.map(
            lambda t: jnp.broadcast_to(t, (n_clients_local,) + t.shape),
            strategy.client_state_init(params))
    if decentralized:
        params = jax.tree.map(
            lambda t: jnp.broadcast_to(t, (n_clients_local,) + t.shape),
            params)
    return {
        "params": params,
        "server": strategy.server_state_init(params),
        "clients": cstate,
    }


def _has_client_state(strategy) -> bool:
    probe = strategy.client_state_init({"x": jnp.zeros(())})
    return bool(jax.tree.leaves(probe))
