"""Campaign planner — heterogeneous sweeps via program-signature buckets.

PR 3's campaign subsystem vmaps S trajectories through ONE compiled program,
which only works when every trajectory traces to the *same* program: scalar
axes ride the vmap, but "FedAvg vs FedProx vs SCAFFOLD across topologies" —
the paper's actual benchmarking pitch — changes the traced computation and
used to mean S sequential processes. The planner closes that gap:

1. ``sweeps.parse_sweep`` now accepts categorical axes (``strategy``,
   ``topology``, ``placement``, ``mode``, ``async_buffer``);
2. the full grid expands row-major exactly like a scalar sweep;
3. every trajectory gets a **program signature** — the canonicalized tuple
   of everything that changes the traced round/event program (strategy kind,
   topology plan, placement, sync/async loop shape, cohort/steps shapes,
   ring size, ...) and nothing that doesn't (scalar-plane knobs, data-plane
   seeds/alphas, schedule-plane exponents);
4. trajectories bucket by signature, and each bucket runs as one vmapped
   launch through the existing ``CampaignExecutor``
   (``runtime/scheduler.py::PlanExecutor`` drives the buckets in lockstep).

A strategy(2) x topology(2) x seed(3) x lr(2) grid is 24 trajectories but
only 4 signatures -> 4 compiled programs, not 24 (compile-count asserted in
tests/test_plan.py via ``Executor.compiled_programs``).

Canonicalization is where buckets merge: ``placement: auto`` resolves to
``spatial`` before hashing; sync signatures ignore async-only knobs (ring
size, buffer) and async signatures ignore sync-only ones (topology,
placement — the event loop aggregates through ``Strategy.server_update``
alone); ``async_buffer`` 0 and 1 are both FedAsync. Two coordinates that
trace to the same program therefore share a bucket by construction.

Determinism contract (tests/test_plan.py): with the lane scheduler off,
every lane of a heterogeneous campaign is bitwise identical to its
independent single run — the bucket executor inherits PR 3's contract, and
the planner only decides *which* lanes share a launch, never what they
compute.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Tuple

from repro.configs.base import FLConfig
from repro.core.sweeps import SweepSpec, expand


def resolve_placement(fl: FLConfig) -> str:
    """The executor's placement resolution (``auto`` -> ``spatial``)."""
    return fl.placement if fl.placement != "auto" else "spatial"


def program_signature(fl: FLConfig, arch: str = "") -> Tuple:
    """Canonical key of the traced round/event program for ``fl``.

    Two configs get equal signatures iff the compiled program that executes
    them is structurally identical, so their trajectories can share one
    vmapped launch. Includes everything trace-shaping: mode and its loop
    shape, strategy kind, client/cohort/step counts, optimizer structure,
    compression, consensus, and (sync) topology/placement or (async) the
    event-loop shape. Excludes the scalar plane (traced runtime values),
    the data plane (seed, alpha, partition) and the schedule plane
    (staleness_exponent, concurrency — host-precomputed arrays).
    """
    mode = fl.mode
    target = int(fl.cohort or fl.n_clients)
    sig: Dict[str, Any] = {
        "arch": arch,
        "mode": mode,
        "strategy": fl.strategy,
        "local_epochs": fl.local_epochs,
        "local_steps": max(fl.local_steps, 1),
        "batch_size": fl.batch_size,
        "client_optimizer": fl.client_optimizer,
        # local_train's momentum carry only exists under sgdm with beta>0
        "client_momentum": (fl.client_momentum
                            if fl.client_optimizer == "sgdm" else 0.0),
        "server_optimizer": fl.server_optimizer,
        "compression": fl.compression,
        "topk_ratio": (fl.topk_ratio if fl.compression == "topk" else 0.0),
        "error_feedback": (fl.error_feedback
                           if fl.compression != "none" else True),
        "n_workers": fl.n_workers,
        "byzantine_workers": fl.byzantine_workers,
        "consensus": (fl.consensus if (fl.n_workers > 1
                                       or fl.byzantine_workers > 0) else ""),
    }
    if fl.max_cohort > 0:
        # ragged client plane: the cohort is padded to max_cohort slots and
        # the draw happens on the host (data/pipeline.SlabStager), so the
        # population and cohort sizes never reach the trace — sweeping
        # n_clients/cohort shares one program instead of splitting buckets
        # (fl.streaming is deliberately absent: the staging backend feeds
        # the same compiled program, that is the bitwise contract)
        sig["ragged_slots"] = int(fl.max_cohort)
    else:
        sig["n_clients"] = fl.n_clients
        sig["cohort"] = target
        # the over-provisioned pool size is a Python int inside cohort_mask
        sig["cohort_pool"] = int(min(
            math.ceil(target * fl.straggler_overprovision), fl.n_clients))
    if mode == "sync":
        # async-only knobs don't reach the sync trace; zeroing them merges
        # buckets that would otherwise split spuriously
        sig["topology"] = fl.topology
        sig["placement"] = resolve_placement(fl)
        sig["gossip_steps"] = (fl.gossip_steps
                               if fl.topology == "decentralized" else 0)
    else:
        # the event loop has no topology/placement; its shape is the
        # FedAsync/FedBuff branch, the events-per-round chunking unit, and
        # the snapshot-ring size
        fedbuff = max(fl.async_buffer, 1) > 1
        sig["fedbuff"] = fedbuff
        sig["events_per_round"] = (fl.async_buffer if fedbuff
                                   else fl.n_clients)
        sig["ring"] = int(fl.max_staleness) + 1
    return tuple(sorted(sig.items()))


@dataclasses.dataclass(frozen=True)
class Bucket:
    """One program signature's worth of lanes (a homogeneous sub-campaign)."""
    index: int
    signature: Tuple
    lane_ids: Tuple[int, ...]          # global lane indices into the grid
    coords: Tuple[Dict[str, Any], ...]
    fls: Tuple[FLConfig, ...]

    @property
    def size(self) -> int:
        """Number of trajectories in this bucket."""
        return len(self.lane_ids)


@dataclasses.dataclass(frozen=True)
class CampaignPlan:
    """The expanded grid, partitioned into signature buckets."""
    spec: SweepSpec
    coords: Tuple[Dict[str, Any], ...]  # row-major, global lane order
    fls: Tuple[FLConfig, ...]
    signatures: Tuple[Tuple, ...]       # per-lane, parallel to coords
    buckets: Tuple[Bucket, ...]         # first-appearance order

    @property
    def size(self) -> int:
        """Total trajectories across all buckets."""
        return len(self.coords)

    def lane_bucket(self, lane: int) -> Tuple[int, int]:
        """(bucket index, index within the bucket) of a global lane id."""
        for b in self.buckets:
            if lane in b.lane_ids:
                return b.index, b.lane_ids.index(lane)
        raise KeyError(f"lane {lane} not in any bucket (grid has "
                       f"{self.size} lanes)")


def build_plan(fl: FLConfig, spec: SweepSpec, arch: str = "") -> CampaignPlan:
    """Expand the grid and bucket the lanes by program signature.

    Pure bookkeeping: lanes keep their row-major global ids, buckets are
    ordered by first appearance, and within a bucket lanes keep grid order —
    so bucket lane ``j`` is always a deterministic function of the spec.
    """
    coords = spec.coords()
    fls = expand(fl, spec)
    sigs = [program_signature(fl_s, arch) for fl_s in fls]
    groups: Dict[Tuple, List[int]] = {}
    for lane, sig in enumerate(sigs):
        groups.setdefault(sig, []).append(lane)
    buckets = tuple(
        Bucket(index=b, signature=sig, lane_ids=tuple(lanes),
               coords=tuple(coords[i] for i in lanes),
               fls=tuple(fls[i] for i in lanes))
        for b, (sig, lanes) in enumerate(groups.items()))
    return CampaignPlan(spec=spec, coords=tuple(coords), fls=tuple(fls),
                        signatures=tuple(sigs), buckets=buckets)
