"""Client-level differential privacy (Geyer et al.): clip + Gaussian noise."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.strategy import Strategy, global_norm


@dataclasses.dataclass(frozen=True)
class DPFedAvg(Strategy):
    """FedAvg with per-client delta clipping and Gaussian noise (DP-FedAvg)."""
    name: str = "dp_fedavg"

    def postprocess(self, delta, client_state, rng):
        """Clip the client delta to ``dp_clip`` and add calibrated noise."""
        clip = self.fl.dp_clip
        sigma = self.fl.dp_noise
        nrm = global_norm(delta)
        scale = jnp.minimum(1.0, clip / jnp.maximum(nrm, 1e-12))
        leaves, treedef = jax.tree.flatten(delta)
        keys = jax.random.split(rng, len(leaves))
        noised = [
            (l * scale + sigma * clip *
             jax.random.normal(k, l.shape, jnp.float32).astype(l.dtype))
            for l, k in zip(leaves, keys)]
        return jax.tree.unflatten(treedef, noised), client_state
