"""Server-side optimizers: FedAvgM (Hsu et al.), FedAdam / FedYogi (Reddi)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.strategy import Strategy, tree_zeros_like


@dataclasses.dataclass(frozen=True)
class FedAvgM(Strategy):
    """FedAvg with server-side Nesterov-style momentum."""
    name: str = "fedavgm"

    def server_state_init(self, params):
        """Zero momentum buffer, shaped like the params."""
        return {"momentum": tree_zeros_like(params)}

    def server_update(self, params, agg_delta, server_state):
        """Fold the aggregate delta into the momentum buffer and apply it."""
        beta = self.fl.server_momentum
        m = jax.tree.map(lambda m, d: beta * m + d.astype(m.dtype),
                         server_state["momentum"], agg_delta)
        new = jax.tree.map(lambda p, mm: p + self.fl.server_lr * mm.astype(p.dtype),
                           params, m)
        return new, {"momentum": m}


@dataclasses.dataclass(frozen=True)
class FedAdam(Strategy):
    """Server-side Adam on the aggregate client delta (FedOpt family)."""
    name: str = "fedadam"
    b1: float = 0.9
    b2: float = 0.99
    eps: float = 1e-3

    def server_state_init(self, params):
        """Zero first/second-moment buffers plus the step counter."""
        return {"m": tree_zeros_like(params), "v": tree_zeros_like(params),
                "t": jnp.zeros((), jnp.int32)}

    def _second_moment(self, v, d):
        return self.b2 * v + (1 - self.b2) * d * d

    def server_update(self, params, agg_delta, server_state):
        """One Adam step treating the aggregate delta as the gradient."""
        t = server_state["t"] + 1
        m = jax.tree.map(lambda m, d: self.b1 * m + (1 - self.b1) * d,
                         server_state["m"], agg_delta)
        v = jax.tree.map(self._second_moment, server_state["v"], agg_delta)
        new = jax.tree.map(
            lambda p, mm, vv: p + (self.fl.server_lr * mm /
                                   (jnp.sqrt(vv) + self.eps)).astype(p.dtype),
            params, m, v)
        return new, {"m": m, "v": v, "t": t}


@dataclasses.dataclass(frozen=True)
class FedYogi(FedAdam):
    """FedAdam variant with Yogi's sign-based second-moment update."""
    name: str = "fedyogi"

    def _second_moment(self, v, d):
        d2 = d * d
        return v - (1 - self.b2) * d2 * jnp.sign(v - d2)
