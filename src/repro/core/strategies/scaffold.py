"""SCAFFOLD (Karimireddy et al.): client/server control variates.

Local gradient is corrected by (c - c_i); after E·K local steps the client
control variate updates via option-II: c_i+ = c_i - c + (x - y_i)/(K·lr),
and the server maintains c = mean(c_i) through the aggregated c-deltas —
this is the "extra state communicated between nodes" the paper cites FLsim
supporting (its requirement (5))."""
from __future__ import annotations

import dataclasses

import jax

from repro.core.strategy import Strategy, tree_zeros_like


@dataclasses.dataclass(frozen=True)
class Scaffold(Strategy):
    """SCAFFOLD: control variates correcting client drift."""
    name: str = "scaffold"

    def server_state_init(self, params):
        """Zero server control variate, shaped like the params."""
        return {"c": tree_zeros_like(params)}

    def client_state_init(self, params):
        """Zero client control variate, shaped like the params."""
        return {"c_i": tree_zeros_like(params)}

    def grad_transform(self, grad, client_state, server_state):
        """Apply the SCAFFOLD correction ``g - c_i + c`` to local grads."""
        return jax.tree.map(lambda g, ci, c: g - ci + c,
                            grad, client_state["c_i"], server_state["c"])

    def client_state_update(self, client_state, server_state, delta,
                            n_local_steps, lr):
        # delta = y_i - x  (client drift); option-II update
        """Option-II update of the client control variate."""
        c_new = jax.tree.map(
            lambda ci, c, d: ci - c - d / (n_local_steps * lr),
            client_state["c_i"], server_state["c"], delta)
        return {"c_i": c_new}

    def server_update(self, params, agg_delta, server_state):
        # agg_delta carries (param_delta, c_delta) when rounds are built with
        # carry_c=True; plain tuple split keeps the hook pytree-generic.
        """Apply the aggregate delta and advance the server control variate."""
        if isinstance(agg_delta, tuple) and len(agg_delta) == 2:
            d_params, d_c = agg_delta
            new_c = jax.tree.map(lambda c, dc: c + dc, server_state["c"], d_c)
            new_p = jax.tree.map(
                lambda p, d: p + self.fl.server_lr * d.astype(p.dtype),
                params, d_params)
            return new_p, {"c": new_c}
        return super().server_update(params, agg_delta, server_state)
