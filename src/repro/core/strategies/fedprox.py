"""FedProx (Li et al.): proximal term against the global model."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.strategy import Strategy


@dataclasses.dataclass(frozen=True)
class FedProx(Strategy):
    """FedAvg with a proximal term pulling local params toward the global."""
    name: str = "fedprox"

    def local_loss(self, base_loss, params, global_params, batch,
                   client_state, rng):
        """Task loss plus ``prox_mu/2 * ||w - w_global||^2``."""
        loss, metrics = base_loss(params, batch, rng)
        mu = self.fl.prox_mu
        prox = sum(jnp.sum(jnp.square((p - g).astype(jnp.float32)))
                   for p, g in zip(jax.tree.leaves(params),
                                   jax.tree.leaves(global_params)))
        return loss + 0.5 * mu * prox, metrics
