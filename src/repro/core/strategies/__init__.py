"""Strategy registry — the seven paper frameworks (Fig. 8) and extras."""
from __future__ import annotations

from repro.configs.base import FLConfig
from repro.core.strategy import Strategy
from repro.core.strategies.fedavgm import FedAvgM, FedAdam, FedYogi
from repro.core.strategies.fedprox import FedProx
from repro.core.strategies.scaffold import Scaffold
from repro.core.strategies.moon import Moon
from repro.core.strategies.dp import DPFedAvg
from repro.core.strategies.compressed import CompressedFedAvg

REGISTRY = {
    "fedavg": lambda fl: Strategy(fl, "fedavg"),
    "fedavgm": FedAvgM,
    "fedadam": FedAdam,
    "fedyogi": FedYogi,
    "fedprox": FedProx,
    "scaffold": Scaffold,
    "moon": Moon,
    "dp_fedavg": DPFedAvg,
    "compressed": CompressedFedAvg,
    # clustered & decentralized (fedstellar-style) are topology-level:
    # clustered -> topology="hierarchical", decentralized -> "decentralized"
    # with plain fedavg local logic.
    "clustered": lambda fl: Strategy(fl, "clustered"),
    "gossip": lambda fl: Strategy(fl, "gossip"),
}


def get_strategy(fl: FLConfig) -> Strategy:
    if fl.strategy not in REGISTRY:
        raise KeyError(f"unknown strategy {fl.strategy!r}: {sorted(REGISTRY)}")
    return REGISTRY[fl.strategy](fl)
