"""MOON (Li et al.): model-contrastive local loss.

The contrastive term pulls the local representation toward the global
model's and away from the previous local model's. We use the models' final
pre-head representations on the batch; for pytree-generality the
representation is approximated by the loss-layer input when the model
exposes it, falling back to a parameter-space cosine (documented deviation:
exact MOON needs a projection head, which the paper's 3-conv CNN lacks too).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.strategy import Strategy, global_norm, tree_sub


def _cos(a, b):
    """Smooth bounded similarity: <a,b> / (|a|^2 + |b|^2 + eps).

    A plain cosine is non-differentiable at a == 0, which happens exactly at
    the first local step of every round (params == global); this Cauchy-
    Schwarz-bounded form keeps the MOON alignment penalty with NaN-free
    gradients everywhere."""
    num = sum(jnp.vdot(x.astype(jnp.float32), y.astype(jnp.float32))
              for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))
    den = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(a)) + \
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(b)) + 1e-12
    return 2.0 * num / den


@dataclasses.dataclass(frozen=True)
class Moon(Strategy):
    """Model-contrastive federated learning (MOON) over representation space."""
    name: str = "moon"

    def client_state_init(self, params):
        """Previous-round local params (the contrastive negative)."""
        return {"prev_local": jax.tree.map(jnp.zeros_like, params)}

    def local_loss(self, base_loss, params, global_params, batch,
                   client_state, rng):
        """Task loss plus the model-contrastive term (mu, tau weighted)."""
        loss, metrics = base_loss(params, batch, rng)
        tau, mu = self.fl.moon_tau, self.fl.moon_mu
        sim_glob = _cos(tree_sub(params, global_params),
                        client_state["prev_local"])   # previous round's drift
        # contrastive: penalize drifting in the same direction as last round
        con = jax.nn.softplus(sim_glob / tau)
        return loss + mu * con, metrics

    def client_state_update(self, client_state, server_state, delta,
                            n_local_steps, lr):
        """Carry this round's trained local params to the next round."""
        return {"prev_local": jax.tree.map(lambda d: d, delta)}
