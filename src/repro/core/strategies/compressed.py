"""Communication-efficient FL (paper refs [15,16]): int8 / top-k delta
compression with error feedback. The quantize-dequantize round trip models
exactly what crosses the network; aggregation of int8 deltas is the
``quant_aggregate`` Pallas kernel's job on TPU."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.strategy import Strategy
from repro.kernels import ref as kref


def _roundtrip_int8(x, block=256):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    fp = jnp.pad(flat, (0, pad))
    q, sc = kref.quantize_blockwise_ref(fp.astype(jnp.float32), block=block)
    deq = (q.astype(jnp.float32).reshape(-1, block) * sc[:, None]).reshape(-1)
    return deq[:flat.shape[0]].reshape(x.shape).astype(x.dtype)


def _topk_mask(x, ratio):
    """Exactly-k sparsification mask. A threshold compare would keep every
    element tied at the k-th magnitude (so the effective k — and the bytes
    on the wire — could exceed ratio*N); scattering top_k's indices keeps
    precisely k, ties broken deterministically by flat index order."""
    flat = jnp.abs(x.astype(jnp.float32)).reshape(-1)
    k = max(1, int(flat.shape[0] * ratio))
    _, idx = jax.lax.top_k(flat, k)
    mask = jnp.zeros_like(flat).at[idx].set(1.0)
    return mask.reshape(x.shape).astype(x.dtype)


@dataclasses.dataclass(frozen=True)
class CompressedFedAvg(Strategy):
    """FedAvg over a lossy compressor with error feedback (int8/topk)."""
    name: str = "compressed"

    def client_state_init(self, params):
        """Zero error-feedback residual, shaped like the params."""
        if self.fl.error_feedback:
            return {"residual": jax.tree.map(jnp.zeros_like, params)}
        return {}

    def postprocess(self, delta, client_state, rng):
        """Compress delta + residual, round-trip it, keep the new residual."""
        ef = self.fl.error_feedback and "residual" in (client_state or {})
        if ef:
            delta = jax.tree.map(lambda d, r: d + r.astype(d.dtype),
                                 delta, client_state["residual"])
        if self.fl.compression == "int8":
            sent = jax.tree.map(_roundtrip_int8, delta)
        elif self.fl.compression == "topk":
            sent = jax.tree.map(
                lambda d: d * _topk_mask(d, self.fl.topk_ratio), delta)
        else:
            sent = delta
        if ef:
            new_res = jax.tree.map(lambda d, s: d - s, delta, sent)
            return sent, {"residual": new_res}
        return sent, client_state

    # -- packed int8 path (kernels/ops.quant_aggregate) -------------------
    @property
    def packs_deltas(self) -> bool:
        """True when the int8 path emits ``PackedDelta`` for fused aggregation."""
        return self.fl.compression == "int8"

    def postprocess_packed(self, delta, client_state, rng):
        """int8 + block-scale emission in the kernel's flat layout. The
        error-feedback residual is computed against the dequantized send
        (exactly what the server will reconstruct), and — because packing
        pads per leaf — it is bitwise the residual the unpacked
        ``_roundtrip_int8`` path would have produced."""
        ef = self.fl.error_feedback and "residual" in (client_state or {})
        if ef:
            delta = jax.tree.map(lambda d, r: d + r.astype(d.dtype),
                                 delta, client_state["residual"])
        pd = packing.quantize_tree(delta)
        if ef:
            sent = packing.unpack_tree(packing.dequant_flat(pd), delta)
            new_res = jax.tree.map(lambda d, s: d - s.astype(d.dtype),
                                   delta, sent)
            return pd, {"residual": new_res}
        return pd, client_state
