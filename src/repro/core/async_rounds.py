"""Event-driven asynchronous FL servers (FedAsync / FedBuff), rendered the
same way PR 1 rendered sync rounds: as one compiled ``lax.scan``.

The scan runs over *server events* — one completed client task per step,
ordered by the virtual clock (``runtime/clock.build_schedule``). Each step,
entirely on device:

1. gathers the arriving client's batch from the partitions staged on device
   (``data/pipeline.gather_one_client_batch`` — bitwise the same draw as the
   sync driver's vmapped gather, keyed by (root, task index, client));
2. trains against the **stale snapshot** the client dispatched with — a ring
   buffer of the last ``max_staleness + 1`` server versions, indexed by the
   schedule's precomputed ring slot;
3. folds the staleness-weighted update into the accumulator and, when the
   schedule says so, applies it through the existing
   ``Strategy.server_update`` machinery and writes the new version into the
   ring.

Two async servers share the one scan body, selected by
``FLConfig.async_buffer``:

- **FedAsync** (buffer <= 1): every accepted arrival applies immediately;
  the update is the mixing form ``alpha_s * (client_model - server_params)``
  with ``alpha_s = (1 + staleness)^-staleness_exponent`` (Xie et al.).
- **FedBuff** (buffer K > 1): arrivals accumulate the staleness-and-size
  weighted mean of K client deltas, then one server update fires
  (Nguyen et al.). With buffer == cohort, zero staleness discount and equal
  client speeds this is *bitwise* synchronous FedAvg (temporal placement) —
  the identity test in tests/test_async.py.

Determinism contract (same as the sync driver): every event's randomness is
keyed by (root, client, absolute task index) and the schedule is
host-precomputed from the seed, so a run chunked as N events per launch is
bitwise-identical to per-event launches.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.core import determinism, packing
from repro.core import probes as probelib
from repro.core.rounds import bind_hyper, freeze_unless, local_train, \
    pop_alive
from repro.core.strategy import Strategy, tree_add, tree_scale, tree_sub, \
    tree_zeros_like
from repro.data.pipeline import gather_event_batch, gather_one_client_batch
from repro.sharding.axes import AxisCtx


def async_init_state(state: dict, ring: int, fl: FLConfig = None,
                     strategy: Strategy = None) -> dict:
    """Augment a sync init_state with the async carries.

    ``hist`` is the param-version ring (every slot starts at version 0, so
    staleness-0 reads are exact); ``acc`` is the open buffer accumulator
    (carried across launch boundaries so chunking can split a buffer group
    without changing the trajectory).

    When ``(fl, strategy)`` select the packed int8 path under FedBuff, the
    open buffer is carried *quantized*: ``qbuf``/``sbuf`` hold the K pending
    client sends in the kernel's (K, N) int8 + (K, N/b) scale layout,
    ``cbuf`` their staleness coefficients and ``bufn`` the count of accepted
    arrivals in the open group. The flush is then ONE fused
    dequant+weighted-sum instead of K incremental f32 adds — and the carries
    keep chunked == unchunked bitwise, same as ``acc``.
    """
    params = state["params"]
    hist = jax.tree.map(lambda t: jnp.repeat(t[None], ring, axis=0), params)
    acc = jax.tree.map(lambda t: jnp.zeros_like(t, jnp.float32), params)
    out = dict(state, hist=hist, acc=acc)
    if (fl is not None and strategy is not None
            and getattr(strategy, "packs_deltas", False)
            and max(fl.async_buffer, 1) > 1):
        n, nblocks = packing.packed_size(params)
        k = fl.async_buffer
        out["qbuf"] = jnp.zeros((k, n), jnp.int8)
        out["sbuf"] = jnp.zeros((k, nblocks), jnp.float32)
        out["cbuf"] = jnp.zeros((k,), jnp.float32)
        out["bufn"] = jnp.zeros((), jnp.int32)
    return out


def build_async_multi(model, strategy: Strategy, fl: FLConfig,
                      batch_size=None, probes: bool = False,
                      on_divergence: str = "report", ragged: bool = False):
    """Fuse ``n_events`` server events into one compiled program.

    Returns ``multi_fn(ctx, state, staged, sched, root, start_event,
    n_events)`` -> ``(state, metrics)``. ``sched`` is the full schedule
    staged on device (``EventSchedule.device_arrays()``); the launch slices
    its own event window in-program, so the host only supplies the start
    offset. ``n_events`` must be a Python int (the scan length). Metrics
    come back stacked with a leading ``n_events`` dim.

    With ``ragged`` (the streaming client plane, ``fl.max_cohort > 0``)
    ``staged`` is not the resident root but the launch's *event slab* —
    per-event rows {"x": (E, Lmax, ...), "y", "len"} staged by a
    ``data.pipeline.SlabStager`` for exactly the clients the schedule says
    arrive in this window. The batch draw stays keyed by the real client id
    from the schedule, so resident and streaming staging are bitwise the
    same program on the same bytes.

    ``state`` needs the async carries from ``async_init_state``.

    ``probes`` (trace-time flag, see ``build_spatial_round``) adds a
    ``metrics["probes"]`` dict per event: ``update_norm`` (0 for buffered
    non-apply events), ``drift_norm`` = ||stale snapshot - server params||
    (staleness in parameter space), ``participation``/``masked_frac`` from
    the schedule's accept bit, ``sat_frac`` on the packed path, and the
    NaN/Inf ``nonfinite`` sentinel (with the opt-in ``on_divergence:
    "freeze"`` select).
    """
    batch_size = batch_size or fl.batch_size
    steps = max(fl.local_steps, 1)
    fedbuff = max(fl.async_buffer, 1) > 1
    packed = strategy.packs_deltas
    freeze_div = probes and on_divergence == "freeze"

    def multi_fn(ctx: AxisCtx, state, staged, sched, root, start_event,
                 n_events: int, hyper=None):
        alive, hyper = pop_alive(hyper)
        fl_h, strategy_h = bind_hyper(fl, strategy, hyper)
        xs = {k: jax.lax.dynamic_slice_in_dim(v, start_event, n_events)
              for k, v in sched.items()}
        scan_xs = (xs, staged) if ragged else xs

        def body(st, scan_x):
            ev, row = scan_x if ragged else (scan_x, None)
            params, server = st["params"], st["server"]
            hist, acc = st["hist"], st["acc"]
            c = ev["client"]
            rkey = determinism.round_key(root, ev["task"])
            stale = jax.tree.map(lambda h: h[ev["read_slot"]], hist)
            cbatch = (gather_event_batch(row, rkey, c, batch_size, steps)
                      if ragged else
                      gather_one_client_batch(staged, rkey, c, batch_size,
                                              steps))
            key = determinism.client_key(rkey, c)
            delta, _, loss = local_train(model, ctx, strategy_h, fl_h, stale,
                                         server, (), cbatch, key,
                                         pack_deltas=packed)
            if packed and fedbuff:
                # the open group is buffered *quantized* in the kernel's
                # (K, N) layout; a rejected arrival keeps its slot's old row
                # (accept — not coeff, which is 0 for accepted zero-weight
                # clients too — gates the write and the count)
                from repro.kernels import ops
                accept = ev["accept"]
                slot = st["bufn"]
                qbuf = st["qbuf"].at[slot].set(
                    jnp.where(accept, delta.q, st["qbuf"][slot]))
                sbuf = st["sbuf"].at[slot].set(
                    jnp.where(accept, delta.scale, st["sbuf"][slot]))
                cbuf = st["cbuf"].at[slot].set(
                    jnp.where(accept, ev["coeff"], st["cbuf"][slot]))
                bufn = st["bufn"] + accept.astype(jnp.int32)

                def do_apply(op):
                    params, server, hist, qbuf, sbuf, cbuf, bufn = op
                    # the FedBuff flush: ONE fused dequant+weighted-sum
                    # over the K buffered int8 sends
                    agg_flat = ops.quant_aggregate(qbuf, sbuf, cbuf)
                    agg = jax.tree.map(
                        lambda a, p: a.astype(p.dtype),
                        packing.unpack_tree(agg_flat, params), params)
                    new_p, new_s = strategy_h.server_update(params, agg,
                                                            server)
                    hist = jax.tree.map(
                        lambda h, p: h.at[ev["write_slot"]].set(p), hist,
                        new_p)
                    return (new_p, new_s, hist, jnp.zeros_like(qbuf),
                            jnp.zeros_like(sbuf), jnp.zeros_like(cbuf),
                            jnp.zeros_like(bufn))

                params, server, hist, qbuf, sbuf, cbuf, bufn = jax.lax.cond(
                    ev["apply"], do_apply, lambda op: op,
                    (params, server, hist, qbuf, sbuf, cbuf, bufn))
                new_st = dict(st, params=params, server=server, hist=hist,
                              qbuf=qbuf, sbuf=sbuf, cbuf=cbuf, bufn=bufn)
            else:
                if packed:
                    # packed FedAsync: the event's single int8 send is
                    # dequantized+coeff-scaled by the fused kernel (C == 1)
                    from repro.kernels import ops
                    deq = ops.quant_aggregate(delta.q[None],
                                              delta.scale[None],
                                              ev["coeff"][None])
                    contrib = jax.tree.map(
                        lambda s_, p, d: ev["coeff"]
                        * (s_.astype(jnp.float32) - p.astype(jnp.float32))
                        + d,
                        stale, params, packing.unpack_tree(deq, params))
                elif fedbuff:
                    contrib = tree_scale(delta, ev["coeff"])
                else:
                    # FedAsync mixing form: alpha * (client_model - server)
                    # == alpha * ((stale - params) + delta); the drift term
                    # pulls the server toward the client's (stale) start
                    # point.
                    contrib = jax.tree.map(
                        lambda s_, p, d: ev["coeff"]
                        * ((s_.astype(jnp.float32) - p.astype(jnp.float32))
                           + d),
                        stale, params, delta)
                acc = tree_add(acc, contrib)

                def do_apply(op):
                    params, server, acc, hist = op
                    agg = jax.tree.map(lambda a, p: a.astype(p.dtype), acc,
                                       params)
                    new_p, new_s = strategy_h.server_update(params, agg,
                                                            server)
                    hist = jax.tree.map(
                        lambda h, p: h.at[ev["write_slot"]].set(p), hist,
                        new_p)
                    return new_p, new_s, tree_zeros_like(acc), hist

                params, server, acc, hist = jax.lax.cond(
                    ev["apply"], do_apply, lambda op: op,
                    (params, server, acc, hist))
                new_st = dict(st, params=params, server=server, hist=hist,
                              acc=acc)
            if probes:
                accept = ev["accept"].astype(jnp.float32)
                upd = probelib.tree_norm(
                    tree_sub(new_st["params"], st["params"]))
                pr = {
                    "update_norm": upd,
                    "drift_norm": probelib.tree_norm(
                        tree_sub(stale, st["params"])),
                    "participation": accept,
                    "masked_frac": 1.0 - accept,
                    "sat_frac": (probelib.sat_frac(delta.q) if packed
                                 else jnp.zeros((), jnp.float32)),
                    "ef_residual_norm": jnp.zeros((), jnp.float32),
                    "nonfinite": probelib.norm_nonfinite(upd),
                }
                if freeze_div:
                    new_st = freeze_unless(1.0 - pr["nonfinite"], new_st, st)
            if alive is not None:
                new_st = freeze_unless(alive, new_st, st)
            metrics = {"loss": loss,
                       "staleness": ev["staleness"].astype(jnp.float32),
                       "applied": ev["apply"].astype(jnp.float32),
                       "client": ev["client"].astype(jnp.float32)}
            if probes:
                if alive is not None:
                    pr = probelib.mask_probes(alive, pr)
                # stacked (P,) vector -> an (E, P) probe plane per launch
                # (see build_multi_round)
                metrics["probes"] = probelib.stack_probes(pr)
            return new_st, metrics

        return jax.lax.scan(body, state, scan_xs)

    return multi_fn
