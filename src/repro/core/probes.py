"""Round-grained probe plane — pure diagnostics computed inside the scans.

PR 7's flight recorder sees the chunk-boundary seams (compile/execute/
stage/io wall-clock) but nothing about what happens *inside* a launch: a
diverging FedProx lane, a saturating int8 quantizer or a starved async
client is invisible until eval. The probe plane closes that gap with a
fixed catalogue of **read-only** per-round diagnostics stacked as an extra
``lax.scan`` output of the round/event programs (``core/rounds.py``,
``core/async_rounds.py``) and drained at chunk boundaries into the flight
recorder (Perfetto "C" counter tracks, one series per campaign lane) plus
a tidy ``probes.csv`` keyed like ``campaign.csv``.

The catalogue (every probe is one f32 scalar per round per lane):

==================  ========================================================
``update_norm``     L2 norm of the server parameter change this round
                    (async: this event — 0 for buffered non-apply events).
``drift_norm``      sync: weighted std of the client deltas around their
                    aggregate, sqrt(E_w||d_c||^2 - ||E_w d_c||^2) — the
                    client-drift magnitude FedProx/SCAFFOLD fight
                    (decentralized: param spread across clients);
                    async: ||stale snapshot - server params|| — staleness
                    measured in parameter space, not versions.
``participation``   sync: cohort clients with nonzero aggregation weight
                    this round; async: 1 if the arrival was accepted.
``masked_frac``     fraction of the total client weight mass excluded this
                    round (cohort subsetting + straggler deadline drops;
                    async: 1 - accept).
``sat_frac``        int8 path: fraction of quantized values saturated at
                    +-127 (a climbing value means the block scales are
                    clipping); 0 on uncompressed paths.
``ef_residual_norm``  int8 spatial path: RMS over cohort clients of the
                    error-feedback residual norm; 0 where clients carry no
                    residual state (temporal/async paths).
``nonfinite``       divergence sentinel: 1.0 when any parameter is
                    NaN/Inf after the round's update, else 0.0.
==================  ========================================================

Contracts (tests/test_probes.py): probes are strictly observational —
probes-on trajectories are **bitwise** probes-off for every driver (they
only add consumers of values the program already computes); probe values
are deterministic across chunkings; dead/padded campaign lanes emit frozen
(zero) probes. The divergence sentinel only *reports* by default; the
opt-in ``on_divergence: freeze`` reuses the PR 4 alive-mask maskwork
(``rounds.freeze_unless``) to freeze a NaN lane at its last finite state
— a runtime select compiled in from launch 1, so a divergence never
recompiles anything.
"""
from __future__ import annotations

import csv
import dataclasses
import pathlib
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# the fixed probe catalogue: the P axis of the (S, R, P) stacked output.
# Order is load-bearing (probes.csv columns and counter names follow it).
PROBE_NAMES = ("update_norm", "drift_norm", "participation", "masked_frac",
               "sat_frac", "ef_residual_norm", "nonfinite")

# async per-event -> per-round reduction (chunking-invariant: rounds are
# fixed event windows). Anything unlisted reduces by mean.
ASYNC_REDUCE = {"update_norm": "max", "participation": "sum",
                "nonfinite": "max"}

_ON_DIVERGENCE = ("report", "freeze")


@dataclasses.dataclass(frozen=True)
class ProbeSpec:
    """Parsed ``probes:`` job section (validated by ``core/jobs.load_job``).

    ``enabled`` compiles the probe outputs into the round/event programs;
    off (the default) traces the exact pre-probe program. ``out_dir``
    receives ``probes.csv`` (falls back to the telemetry out_dir, then the
    executor's out_dir; rows stay in memory when none is set).
    ``on_divergence`` is the sentinel's action: ``report`` (default) only
    emits the probe; ``freeze`` holds a lane at its last finite state."""
    enabled: bool = False
    out_dir: Optional[str] = None
    on_divergence: str = "report"

    def __post_init__(self):
        if self.on_divergence not in _ON_DIVERGENCE:
            raise ValueError(
                f"probes.on_divergence must be one of {_ON_DIVERGENCE}, "
                f"got {self.on_divergence!r}")
        if self.on_divergence == "freeze" and not self.enabled:
            raise ValueError(
                "probes.on_divergence: freeze needs probes.enabled: true "
                "(the sentinel that drives the freeze is a probe)")

    @property
    def freeze(self) -> bool:
        """True when divergence policy holds lanes at their last finite state."""
        return self.enabled and self.on_divergence == "freeze"

    @classmethod
    def from_job(cls, job) -> "ProbeSpec":
        """Build from a job's ``probes:`` section (absent -> disabled)."""
        p = (getattr(job, "raw", None) or {}).get("probes") or {}
        return cls(enabled=bool(p) and bool(p.get("enabled", True)),
                   out_dir=p.get("out_dir"),
                   on_divergence=p.get("on_divergence", "report"))


# ---------------------------------------------------------------------------
# In-program probe arithmetic (pure jnp; every helper is a read-only
# consumer of values the round/event body already computed)
# ---------------------------------------------------------------------------

def tree_sq_norm(tree) -> jax.Array:
    """Sum of squares over every leaf, accumulated in f32."""
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return jnp.zeros((), jnp.float32)
    return sum(jnp.sum(jnp.square(leaf.astype(jnp.float32)))
               for leaf in leaves)


def tree_norm(tree) -> jax.Array:
    """Global L2 norm over a pytree's leaves."""
    return jnp.sqrt(tree_sq_norm(tree))


def tree_nonfinite(tree) -> jax.Array:
    """1.0 when any leaf holds a NaN/Inf, else 0.0 (the sentinel)."""
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return jnp.zeros((), jnp.float32)
    bad = sum(jnp.sum(~jnp.isfinite(leaf.astype(jnp.float32)))
              for leaf in leaves)
    return (bad > 0).astype(jnp.float32)


def stack_probes(pr: dict) -> jax.Array:
    """Probe dict -> one ``(P,)`` f32 vector in ``PROBE_NAMES`` order (the
    P axis of the launch's (R, P) / (S, R, P) probe plane — one scan
    output and one device->host transfer instead of seven)."""
    return jnp.stack([pr[name].astype(jnp.float32)
                      for name in PROBE_NAMES])


def norm_nonfinite(norm) -> jax.Array:
    """The sentinel read off the already-computed update norm: starting
    from finite params, any NaN/Inf entering ``new_params`` makes the
    (new - old) delta nonfinite, which poisons its sum-of-squares — so one
    scalar finiteness check replaces a full parameter sweep per round."""
    return (~jnp.isfinite(norm)).astype(jnp.float32)


def per_client_sq_norms(deltas) -> jax.Array:
    """(C,) sum-of-squares per client of a tree stacked on a leading C."""
    leaves = jax.tree.leaves(deltas)
    return sum(jnp.sum(jnp.square(leaf.astype(jnp.float32)),
                       axis=tuple(range(1, leaf.ndim)))
               for leaf in leaves)


def packed_sq_norms(q, scale) -> jax.Array:
    """(C,) sum-of-squares of dequantized ``(C, N) int8`` sends, computed
    blockwise from the scales — no (C, N) f32 materialization (XLA fuses
    the cast into the reduce)."""
    c, n = q.shape
    nb = scale.shape[-1]
    qsq = jnp.sum(jnp.square(q.astype(jnp.float32)).reshape(c, nb, n // nb),
                  axis=-1)
    return jnp.sum(qsq * jnp.square(scale), axis=-1)


def packed_sq_norm(q, scale) -> jax.Array:
    """Sum-of-squares of one dequantized ``(N,) int8`` send — the
    per-client in-loop variant of ``packed_sq_norms``."""
    nb = scale.shape[-1]
    qsq = jnp.sum(jnp.square(q.astype(jnp.float32)).reshape(nb, -1),
                  axis=-1)
    return jnp.sum(qsq * jnp.square(scale))


def sat_frac(q) -> jax.Array:
    """Fraction of int8 values saturated at the +-127 clip points."""
    return jnp.mean((jnp.abs(q.astype(jnp.int32)) >= 127)
                    .astype(jnp.float32))


def drift_from_moments(weights, per_client_sq, agg_sq, psum=lambda x: x):
    """sqrt(E_w ||d_c||^2 - ||agg||^2), clipped at 0 — the weighted std of
    the client deltas around their aggregate via the variance identity
    (works for scanned clients too: only weighted *sums* are needed, never
    the stacked deltas). ``psum`` folds cross-chip client shards."""
    wsum = psum(weights.sum())
    mean_sq = psum((weights * per_client_sq).sum()) \
        / jnp.maximum(wsum, 1e-12)
    return jnp.sqrt(jnp.maximum(mean_sq - agg_sq, 0.0))


def mask_probes(alive, pr: dict) -> dict:
    """Freeze a dead/padded lane's probes at 0 (``alive`` is the campaign
    lane mask — scalar per lane under the vmap). A dropped lane's state
    select discards its computed update, so its would-be probe values
    describe arithmetic no trajectory keeps; zeroing them keeps the probe
    stream as frozen as the state."""
    keep = alive > 0
    return {k: jnp.where(keep, v, jnp.zeros_like(v)) for k, v in pr.items()}


# ---------------------------------------------------------------------------
# Host-side async extras (pure functions of the precomputed schedule /
# already-emitted metrics — zero device cost)
# ---------------------------------------------------------------------------

def buffer_occupancy(accept, apply) -> np.ndarray:
    """(E,) accepted-not-yet-applied arrivals after each event, from the
    schedule's host arrays (the scan body writes the arrival first, then
    flushes — so an apply event's occupancy reads 0)."""
    accept = np.asarray(accept).astype(np.int64)
    apply = np.asarray(apply).astype(bool)
    occ = np.empty(len(accept), np.int64)
    run = 0
    for i in range(len(accept)):
        run += accept[i]
        if apply[i]:
            run = 0
        occ[i] = run
    return occ


def staleness_hist(staleness, max_staleness: int) -> dict:
    """Counter values ``{"s0": n0, ...}`` binning a window's staleness
    stream (the last bucket absorbs >= max_staleness)."""
    s = np.clip(np.asarray(staleness).astype(np.int64).ravel(), 0,
                max_staleness)
    counts = np.bincount(s, minlength=max_staleness + 1)
    return {f"s{i}": int(c) for i, c in enumerate(counts)}


# ---------------------------------------------------------------------------
# probes.csv — tidy append-only table, keyed like campaign.csv
# ---------------------------------------------------------------------------

class ProbeTable:
    """Append-only ``probes.csv`` writer (one row per (lane,) round).

    The probe catalogue is fixed, so — unlike ``campaign.AppendTable`` —
    columns never grow: the file truncates on the first flush of a process
    (matching ``telemetry.jsonl``'s one-file-per-run convention) and every
    later flush appends only the new rows."""

    def __init__(self, path, lead):
        self.path = pathlib.Path(path)
        self.lead = list(lead)
        self._fieldnames = None
        self._fh = None
        self._writer = None

    def flush(self, rows) -> Optional[pathlib.Path]:
        """Append ``rows`` (the new rows only — the caller buffers). The
        file handle stays open across flushes (a boundary-per-round run
        would otherwise pay an open/close per round); every flush ends on
        a flushed handle, so the csv is readable mid-run."""
        if not rows:
            return self.path if self._fieldnames else None
        if self._fieldnames is None:
            self._fieldnames = self.lead + sorted(
                {k for r in rows for k in r} - set(self.lead))
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self._fh = open(self.path, "w", newline="")
            self._writer = csv.DictWriter(self._fh,
                                          fieldnames=self._fieldnames)
            self._writer.writeheader()
        self._writer.writerows(rows)
        self._fh.flush()
        return self.path


def read_probes(csv_path) -> list:
    """Read a ``probes.csv`` back into tidy rows (floats where numeric,
    ints for round/traj, categorical coordinates as strings)."""
    def cell(k, v):
        if k in ("round", "traj", "seed", "bucket", "lane"):
            return int(float(v))
        try:
            return float(v)
        except ValueError:
            return v
    with open(csv_path, newline="") as f:
        return [{k: cell(k, v) for k, v in row.items() if v != ""}
                for row in csv.DictReader(f)]
