"""Seed synchronization (paper RQ6).

FLsim synchronizes node seeds via env vars + per-library deterministic modes.
In JAX determinism is structural: one root key, `fold_in` chains keyed by
(round, client, step). Bitwise reproducibility is asserted by
tests/test_determinism.py and benchmarks/tab12_reproducibility.py.
"""
from __future__ import annotations

import jax


def root_key(seed: int) -> jax.Array:
    """Root PRNG key for a run, derived from the job seed alone."""
    return jax.random.PRNGKey(seed)


def round_key(key, round_idx) -> jax.Array:
    """Per-round key: the root key folded with the absolute round index."""
    return jax.random.fold_in(key, round_idx)


def client_key(key, client_id) -> jax.Array:
    """Per-client key derived from a round key (tag 0x11C)."""
    return jax.random.fold_in(jax.random.fold_in(key, 0x11C), client_id)


def step_key(key, step) -> jax.Array:
    """Per-local-step key derived from a client key (tag 0x57E)."""
    return jax.random.fold_in(jax.random.fold_in(key, 0x57E), step)


def batch_key(round_key_, client_id) -> jax.Array:
    """Key for a client's on-device batch draw in one round. Derived from the
    round key so the device-resident driver samples identical batches for a
    given (seed, round) regardless of how rounds are chunked into launches."""
    return jax.random.fold_in(jax.random.fold_in(round_key_, 0xBA7C),
                              client_id)


def cohort_key(seed, round_idx) -> jax.Array:
    """Key for cohort selection / fault outcomes in one round. ``round_idx``
    may be a traced scalar (the multi-round scan passes it in-program)."""
    return jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(0xC047), seed), round_idx)
