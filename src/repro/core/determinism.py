"""Seed synchronization (paper RQ6).

FLsim synchronizes node seeds via env vars + per-library deterministic modes.
In JAX determinism is structural: one root key, `fold_in` chains keyed by
(round, client, step). Bitwise reproducibility is asserted by
tests/test_determinism.py and benchmarks/tab12_reproducibility.py.
"""
from __future__ import annotations

import jax


def root_key(seed: int) -> jax.Array:
    return jax.random.PRNGKey(seed)


def round_key(key, round_idx) -> jax.Array:
    return jax.random.fold_in(key, round_idx)


def client_key(key, client_id) -> jax.Array:
    return jax.random.fold_in(jax.random.fold_in(key, 0x11C), client_id)


def step_key(key, step) -> jax.Array:
    return jax.random.fold_in(jax.random.fold_in(key, 0x57E), step)
