"""Heterogeneous campaign execution + lane scheduling (successive halving).

``PlanExecutor`` is the runtime half of the campaign planner
(``core/plan.py``): it instantiates one ``CampaignExecutor`` per program-
signature bucket and drives all buckets in **lockstep** over round chunks —
so a heterogeneous strategy x topology x seed grid runs as B vmapped
compiled programs (B = #signatures), not S sequential processes, and a
campaign-wide scheduler can compare lanes *across* buckets at every chunk
boundary.

The lane scheduler implements successive halving / early stopping on top of
the per-round tidy table: at each rung it ranks the alive lanes by the
latest value of a metric and drops the worst ``1 - 1/eta`` fraction. A drop
never recompiles anything — the per-lane ``alive`` mask is a runtime input
to the compiled programs (``rounds.freeze_unless``), so a dropped lane's
state simply freezes at its drop round, its rows stop landing in the table,
and the drop decision is recorded in the ledger (kind ``lane_drop``) for
auditable campaign provenance.

Contracts (tests/test_plan.py):
- scheduler off: every lane bitwise-equals its independent single run (the
  bucket executors inherit PR 3's contract; the planner only groups);
- scheduler on: a surviving lane is STILL bitwise its full single run
  (vmap lanes are independent — the mask only gates state writes), and a
  dropped lane's params equal its single run truncated at the drop round;
- the merged ``campaign.csv`` is keyed by (bucket, lane, sweep coords) and
  appends per chunk;
- resume re-adopts drop decisions from the decision journal
  (``decisions.jsonl``, one entry per visited boundary) and re-decides at
  most the one tail boundary a crash can leave unrecorded — from the
  re-adopted table, whose rows regenerate bitwise, so the replay is
  deterministic.
"""
from __future__ import annotations

import dataclasses
import math
import pathlib
from typing import Any, Callable, Dict, List, Optional

from repro.core.jobs import rebind
from repro.core.plan import build_plan
from repro.runtime.campaign import (AppendTable, CampaignExecutor,
                                    write_parquet)
from repro.telemetry.recorder import FlightRecorder


@dataclasses.dataclass(frozen=True)
class SuccessiveHalving:
    """Rung policy: at every ``rung_every`` rounds keep the best
    ``ceil(alive / eta)`` lanes (never fewer than ``min_lanes``) by
    ``metric`` (``mode`` = "min" for losses, "max" for accuracies).

    ``decide`` is a pure function of (round, per-lane metric values), which
    is what makes resume-replay deterministic."""
    metric: str = "loss"
    mode: str = "min"                 # min | max
    rung_every: int = 1               # rounds between rungs
    eta: float = 2.0                  # keep 1/eta per rung
    min_lanes: int = 1

    def __post_init__(self):
        if self.mode not in ("min", "max"):
            raise ValueError(f"mode must be 'min' or 'max', got {self.mode!r}")
        if self.eta <= 1.0:
            raise ValueError(f"eta must be > 1, got {self.eta}")
        if self.rung_every < 1:
            raise ValueError(f"rung_every must be >= 1, got {self.rung_every}")

    def is_rung(self, round_idx: int, prev_round: Optional[int] = None):
        """Did a rung fire between ``prev_round`` (exclusive) and
        ``round_idx`` (inclusive)? Chunk boundaries are the only rounds a
        campaign can act on, so a rung is "crossed" — not "landed on
        exactly": rung_every=5 with rounds_per_launch=4 still halves at
        boundaries 8, 12, 16, ... (one rung each), instead of silently
        skipping every rung that isn't a multiple of the chunk size."""
        if prev_round is None:
            prev_round = round_idx - 1
        return round_idx > 0 and \
            round_idx // self.rung_every > prev_round // self.rung_every

    def decide(self, round_idx: int, metrics: Dict[Any, float],
               prev_round: Optional[int] = None) -> List[Any]:
        """Lanes to drop at this boundary (empty off-rung). ``metrics``
        maps lane keys -> the metric's latest value; ties break by lane key
        (grid order), so decisions are deterministic. ``prev_round`` is the
        previous boundary (rung-crossing detection); omitted, only exact
        rung multiples fire."""
        if not self.is_rung(round_idx, prev_round) \
                or len(metrics) <= self.min_lanes:
            return []
        sign = 1.0 if self.mode == "min" else -1.0
        ranked = sorted(metrics, key=lambda k: (sign * metrics[k], k))
        keep = max(self.min_lanes, math.ceil(len(ranked) / self.eta))
        return ranked[keep:]


@dataclasses.dataclass
class PlanExecutor:
    """Bucketed heterogeneous campaign: one ``CampaignExecutor`` per
    program signature, advanced in lockstep, with optional lane scheduling.

    ``job`` must carry a ``sweep:`` section (categorical axes welcome).
    ``out_dir`` (if set) receives the merged table ``campaign.csv`` keyed
    by (bucket, lane, sweep coords), the ``decisions.jsonl`` journal
    (scheduler on) and one sub-table per bucket; ``ckpt_dir`` shards into
    per-bucket checkpoint dirs, and a scheduled checkpointed campaign
    requires ``out_dir`` (resume re-adopts the drop decisions from it).
    """
    job: Any
    scheduler: Optional[SuccessiveHalving] = None
    out_dir: Optional[str] = None
    ckpt_dir: Optional[str] = None
    eval_fn: Optional[Callable] = None
    # Shard each bucket's sweep axis over this many devices (0 = no
    # sharding). Buckets shard *independently* — each pads its own lane
    # count up to a multiple of the device count with dead lanes — while
    # scheduler decisions stay host-side, computed from the tidy table,
    # whose rows are bitwise device-count-invariant: the same campaign
    # drops the same lanes on 1 device and on n.
    lane_devices: int = 0

    def scaffold(self):
        if self.job.sweep is None:
            raise ValueError("PlanExecutor needs a job with a sweep: "
                             "section (see core/sweeps.py for the axes)")
        if self.scheduler is not None and self.ckpt_dir and not self.out_dir:
            raise ValueError(
                "a scheduled campaign with ckpt_dir needs out_dir: drop "
                "decisions replay from the results table + decision "
                "journal on resume, and without them previously dropped "
                "lanes would silently resurrect")
        self.plan = build_plan(self.job.fl, self.job.sweep, self.job.arch)
        # ONE shared flight recorder for the whole plan: each bucket's
        # executor records onto its own track ("bucket<i>"), the lockstep
        # loop onto "plan" — so the exported trace shows per-bucket launch
        # lanes side by side under a single clock.
        self.recorder = FlightRecorder.from_job(self.job,
                                                fallback_dir=self.out_dir)
        self.execs: List[CampaignExecutor] = []
        for bucket in self.plan.buckets:
            sub = f"bucket{bucket.index}"
            ex = CampaignExecutor(
                rebind(self.job, bucket.fls[0]),
                lanes=(bucket.coords, bucket.fls),
                out_dir=(str(pathlib.Path(self.out_dir) / sub)
                         if self.out_dir else None),
                ckpt_dir=(str(pathlib.Path(self.ckpt_dir) / sub)
                          if self.ckpt_dir else None),
                eval_fn=self.eval_fn, parquet=False,
                lane_scheduling=self.scheduler is not None,
                lane_devices=self.lane_devices,
                recorder=self.recorder, telemetry_track=sub)
            ex.scaffold()
            self.execs.append(ex)
        # a crash can leave buckets at different rounds; the lockstep loop
        # lets the laggards catch up (run(rounds=r) no-ops past r)
        self.round_idx = min(ex.round_idx for ex in self.execs)
        self.dropped: Dict[int, int] = {}      # global lane -> drop round
        self._merged: list = []                # incremental merged rows
        self._taken = [0] * len(self.execs)    # per-bucket rows consumed
        self._table = (AppendTable(pathlib.Path(self.out_dir) /
                                   "campaign.csv")
                       if self.out_dir else None)
        self._journal = (pathlib.Path(self.out_dir) / "decisions.jsonl"
                         if self.out_dir and self.scheduler is not None
                         else None)
        if self.scheduler is not None and self.round_idx > 0:
            self._replay_decisions()
        elif self._journal is not None and self._journal.exists():
            self._journal.unlink()             # fresh campaign, stale file
        return self

    # -- lockstep chunk loop ----------------------------------------------
    def run(self, rounds: Optional[int] = None):
        fl = self.job.fl
        rounds = rounds or fl.rounds
        # the scheduler needs control at every chunk boundary; without one
        # each bucket can run its whole horizon in one call (the bucket's
        # own chunk loop still does the per-chunk boundary I/O)
        chunk = (max(fl.rounds_per_launch, 1)
                 if self.scheduler is not None else rounds)
        rec = self.recorder
        while self.round_idx < rounds:
            prev = self.round_idx
            n = min(chunk, rounds - prev)
            target = prev + n
            for ex in self.execs:
                ex.run(rounds=target)
            self.round_idx = target
            if self.scheduler is not None:
                with rec.span("scheduler", track="plan", round=target):
                    dropped = self._apply_decisions(target, prev,
                                                    record=True)
                    self._journal_append(target, prev, dropped)
            if self._table is not None:
                with rec.span("table_flush", track="plan"):
                    self._table.flush(self.rows(), self._lead_columns())
        if self.out_dir:
            with rec.span("parquet", track="plan"):
                self._write_parquet()
            if any(ex.probe_rows for ex in self.execs):
                with rec.span("probe_flush", track="plan"):
                    self.write_probes()
            if any(ex.comms_rows for ex in self.execs):
                with rec.span("comms_flush", track="plan"):
                    self.write_comms()
        rec.flush()
        return self

    # -- lane scheduling ---------------------------------------------------
    def _lane_metrics(self, round_idx: int):
        """Per-lane metric (alive lanes only) from the tidy tables: the
        rows of round ``round_idx - 1``, the chunk tail every bucket just
        flushed. Scans each table backwards and stops once every alive
        lane reported, so the live path reads O(S * chunk) rows. Also
        returns the column names seen on those rows (typo diagnostics)."""
        name = self.scheduler.metric
        out: Dict[int, float] = {}
        seen: set = set()
        for bucket, ex in zip(self.plan.buckets, self.execs):
            want = set(ex.alive_lanes())
            for row in reversed(ex.results):
                if not want:
                    break
                if row["round"] == round_idx - 1 and row["traj"] in want:
                    want.discard(row["traj"])
                    seen.update(row)
                    if name in row:
                        out[bucket.lane_ids[row["traj"]]] = float(row[name])
        return out, seen

    def _apply_decisions(self, round_idx: int, prev_round: int,
                         record: bool) -> List[int]:
        metrics, seen_cols = self._lane_metrics(round_idx)
        if not metrics and seen_cols and \
                self.scheduler.is_rung(round_idx, prev_round):
            import difflib
            hint = difflib.get_close_matches(self.scheduler.metric,
                                             sorted(seen_cols), n=1)
            suffix = (f" — did you mean {hint[0]!r}?" if hint
                      else f"; table columns: {sorted(seen_cols)}")
            raise KeyError(
                f"lane scheduler metric {self.scheduler.metric!r} appears "
                f"in no round-{round_idx - 1} row{suffix}")
        lanes = self.scheduler.decide(round_idx, metrics, prev_round)
        for lane in lanes:
            self._drop(lane, round_idx, record,
                       metric=metrics.get(lane))
        return lanes

    def _drop(self, lane: int, round_idx: int, record: bool, metric=None):
        b, j = self.plan.lane_bucket(lane)
        self.execs[b].drop_lane(j)
        self.dropped[lane] = round_idx
        if record and self.job.ledger is not None:
            payload = {"lane": lane, "bucket": b,
                       "coord": dict(self.plan.coords[lane])}
            if metric is not None:
                payload[self.scheduler.metric] = metric
            self.job.ledger.append(round_idx, "lane_drop", payload)

    def _journal_append(self, round_idx: int, prev_round: int, dropped):
        """Record the boundary in the decision journal — the exact
        boundary sequence the live loop visited (it depends on the run()
        horizons, so a resume cannot reconstruct it from the chunk size
        alone) plus which lanes were dropped there."""
        if self._journal is None:
            return
        import json
        with open(self._journal, "a") as f:
            f.write(json.dumps({"round": round_idx, "prev": prev_round,
                                "dropped": list(dropped)}) + "\n")

    def _replay_decisions(self):
        """Resume path: re-adopt the decision journal — the recorded
        boundaries (≤ the resumed round) re-apply their drops verbatim
        (and re-record them into this process's fresh ledger); entries
        past the resumed round are discarded (the resumed run will re-make
        them identically — decisions are a pure function of the table,
        which regenerates bitwise). Only the crash window between a
        checkpoint save and its boundary's journal append can leave the
        tail boundary unrecorded; that boundary re-decides from the
        re-adopted table, which is exactly what the live run would have
        done there."""
        import json
        resumed = self.round_idx
        kept, last = [], 0
        if self._journal is not None and self._journal.exists():
            for line in self._journal.read_text().splitlines():
                e = json.loads(line)
                if e["round"] <= resumed:
                    kept.append(e)
                    for lane in e["dropped"]:
                        self._drop(lane, e["round"], record=True)
                    last = max(last, e["round"])
            # truncate: boundaries past the resume point get re-made live
            with open(self._journal, "w") as f:
                for e in kept:
                    f.write(json.dumps(e) + "\n")
        if last < resumed:
            dropped = self._apply_decisions(resumed, last, record=True)
            self._journal_append(resumed, last, dropped)

    # -- merged results ----------------------------------------------------
    def _lead_columns(self):
        return ["bucket", "lane", *self.plan.spec.names, "traj", "round"]

    def rows(self) -> list:
        """The merged tidy table: every bucket's rows keyed by (bucket,
        global lane, sweep coords), in (round, lane) order. Maintained
        incrementally — each call merges only rows that appeared since the
        last one, so per-boundary cost is O(S * chunk), not O(S * R)."""
        new = []
        for b, (bucket, ex) in enumerate(zip(self.plan.buckets,
                                             self.execs)):
            for row in ex.results[self._taken[b]:]:
                new.append({"bucket": bucket.index,
                            "lane": bucket.lane_ids[row["traj"]], **row})
            self._taken[b] = len(ex.results)
        # new rows all belong to rounds past the already-merged prefix, so
        # sorting just the batch keeps the whole list in (round, lane) order
        new.sort(key=lambda r: (r["round"], r["lane"]))
        self._merged.extend(new)
        return self._merged

    def write_results(self, out_dir=None):
        out = pathlib.Path(out_dir or self.out_dir or ".")
        table = AppendTable(out / "campaign.csv")
        path = table.flush(self.rows(), self._lead_columns())
        self._write_parquet(out)
        return path

    def probe_rows(self) -> list:
        """The merged probe table: every bucket's probe rows keyed like the
        merged results — (bucket, global lane, sweep coords, traj, round)
        — in (round, lane) order. The per-bucket ``probes_bucket<i>.csv``
        files stay the incrementally-flushed artifacts."""
        out = []
        for bucket, ex in zip(self.plan.buckets, self.execs):
            for row in ex.probe_rows:
                out.append({"bucket": bucket.index,
                            "lane": bucket.lane_ids[row["traj"]], **row})
        out.sort(key=lambda r: (r["round"], r["lane"]))
        return out

    def write_probes(self, out_dir=None):
        """Write the merged ``probes.csv`` (the lockstep loop calls this at
        the end of a probed run; also an explicit export entry point)."""
        from repro.core.probes import ProbeTable
        rows = self.probe_rows()
        if not rows:
            return None
        out = pathlib.Path(out_dir or self.out_dir or ".")
        out.mkdir(parents=True, exist_ok=True)
        table = ProbeTable(out / "probes.csv",
                           ["bucket", "lane", *self.plan.spec.names,
                            "traj", "round"])
        return table.flush(rows)

    def comms_rows(self) -> list:
        """The merged comms table: every bucket's comms rows keyed like the
        merged results — (bucket, global lane, sweep coords, traj, round)
        — in (round, lane) order. The per-bucket ``comms_bucket<i>.csv``
        files stay the incrementally-flushed artifacts."""
        out = []
        for bucket, ex in zip(self.plan.buckets, self.execs):
            for row in ex.comms_rows:
                out.append({"bucket": bucket.index,
                            "lane": bucket.lane_ids[row["traj"]], **row})
        out.sort(key=lambda r: (r["round"], r["lane"]))
        return out

    def write_comms(self, out_dir=None):
        """Write the merged ``comms.csv`` (the lockstep loop calls this at
        the end of a comms-accounted run; also an explicit export entry
        point)."""
        from repro.core.probes import ProbeTable
        rows = self.comms_rows()
        if not rows:
            return None
        out = pathlib.Path(out_dir or self.out_dir or ".")
        out.mkdir(parents=True, exist_ok=True)
        table = ProbeTable(out / "comms.csv",
                           ["bucket", "lane", *self.plan.spec.names,
                            "traj", "round"])
        return table.flush(rows)

    def _write_parquet(self, out_dir=None):
        write_parquet(self.rows(), self._lead_columns(),
                      out_dir or self.out_dir or ".")

    # -- introspection -----------------------------------------------------
    def lane_params(self, lane: int):
        """Global lane ``lane``'s params (bitwise its single run's, frozen
        at the drop round if the scheduler dropped it)."""
        b, j = self.plan.lane_bucket(lane)
        return self.execs[b].trajectory_params(j)

    def compiled_programs(self) -> int:
        """Total compiled programs across buckets — the tentpole claim:
        equals the number of distinct program signatures (per scan length),
        not the number of trajectories."""
        return sum(ex.compiled_programs() for ex in self.execs)

    @property
    def S(self) -> int:
        return self.plan.size
