"""Host-level FL executor — the faithful rendering of paper Algorithm 1.

The Logic Controller's ProcessPhase x NodeStage machine survives here as the
*host* round loop: everything that is genuinely I/O (data staging, straggler
deadlines, checkpoint/restart, ledger records, dashboards). The compiled
round program (core/rounds.py) is the part that was polling/signalling in
the paper and is now a single XLA program.

ProcessPhase: 0=init 1=local-learning 2=aggregation (paper §2.3).
NodeStage:    0=not-ready 1=ready-for-job 2=ready-with-dataset
              3=busy 4=waiting/complete.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt as ckpt_mod
from repro.core import determinism
from repro.core.blockchain import param_digest
from repro.core.kvstore import KVStore
from repro.core.rounds import build_spatial_round, init_state
from repro.metrics.logger import PerformanceLogger
from repro.runtime.faults import select_cohort
from repro.sharding.axes import AxisCtx


@dataclasses.dataclass
class Executor:
    job: Any                              # core.jobs.Job
    ctx: AxisCtx = AxisCtx()
    ckpt_dir: Optional[str] = None
    logger: Optional[PerformanceLogger] = None
    eval_fn: Optional[Callable] = None    # (params) -> dict of metrics

    def __post_init__(self):
        self.kv = KVStore()
        self.logger = self.logger or PerformanceLogger(run_name=self.job.name)
        self.round_fn = jax.jit(
            lambda s, b, w, r: build_spatial_round(
                self.job.model, self.job.strategy, self.job.fl)(
                self.ctx, s, b, w, r))

    # -- Alg. 1 lines 1-15: scaffold ------------------------------------
    def scaffold(self):
        fl = self.job.fl
        self.kv.set_process_phase(0)
        nodes = [f"client_{i}" for i in range(fl.n_clients)]
        for n in nodes:                      # "DownloadJobConfig <- True"
            self.kv.set_node_stage(n, 1)
        x, y, parts = self.job.dataset.distribute_into_chunks(
            fl.partition, fl.n_clients, fl.dirichlet_alpha)
        self.data = (x, y, parts)
        for n in nodes:                      # "DownloadDataset"
            self.kv.set_node_stage(n, 2)
        self.nodes = nodes
        key = determinism.root_key(fl.seed)
        self.state = init_state(self.job.model, self.job.strategy, fl, key,
                                n_clients_local=fl.n_clients)
        self.round_idx = 0
        # restart path (fault tolerance): resume from the newest manifest
        if self.ckpt_dir:
            last = ckpt_mod.latest_round(self.ckpt_dir)
            if last is not None:
                self.state, extra = ckpt_mod.restore(
                    self.ckpt_dir, last, self.state)
                self.round_idx = extra["next_round"]
        return self

    # -- Alg. 1 lines 16-57: round loop ----------------------------------
    def run(self, rounds: Optional[int] = None):
        fl = self.job.fl
        rounds = rounds or fl.rounds
        x, y, parts = self.data
        root = determinism.root_key(fl.seed)
        while self.round_idx < rounds:
            r = self.round_idx
            rkey = determinism.round_key(root, r)
            # phase 1: cohort selection with straggler mitigation
            self.kv.set_process_phase(1)
            target = fl.cohort or fl.n_clients
            cohort = select_cohort(self.job.fault, r,
                                   np.arange(fl.n_clients), target,
                                   fl.straggler_overprovision)
            batches, weights = [], []
            for c in range(fl.n_clients):
                steps = max(fl.local_steps, 1)
                b, _ = type(self.job.dataset).client_batches(
                    x, y, parts[c], batch_size=min(32, len(parts[c])),
                    n_steps=steps, seed=fl.seed * 7919 + c + r * 104729)
                batches.append(b)
                # dropped/straggler clients get zero weight (unbiased drop)
                weights.append(float(len(parts[c])) if c in cohort else 0.0)
            batch = jax.tree.map(lambda *t: np.stack(t), *batches)
            weights = jnp.asarray(weights, jnp.float32)
            for n in self.nodes:
                self.kv.set_node_stage(n, 3)
            # phases 1->2 happen inside the compiled round
            self.kv.set_process_phase(2)
            t0 = time.time()
            self.state, metrics = self.round_fn(self.state, batch, weights,
                                                rkey)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.time() - t0
            for n in self.nodes:
                self.kv.set_node_stage(n, 4)
            # ledger: provenance of the chosen global model
            if self.job.ledger is not None:
                dig = param_digest(self.state["params"])
                self.job.ledger.record_global(r, self.state["params"])
                self.kv.publish(f"global_digest/{r}", dig)
            row = dict(metrics, round_s=dt)
            if self.eval_fn is not None:
                row.update({k: float(v) for k, v in
                            self.eval_fn(self.state["params"]).items()})
            self.logger.log_round(r, **row)
            self.round_idx += 1
            if self.ckpt_dir and fl.checkpoint_every and \
                    self.round_idx % fl.checkpoint_every == 0:
                ckpt_mod.save(self.ckpt_dir, self.round_idx, self.state,
                              extra={"next_round": self.round_idx},
                              async_write=False)
        return self.state, self.logger
