"""Host-level FL executor — the faithful rendering of paper Algorithm 1.

The Logic Controller's ProcessPhase x NodeStage machine survives here as the
*host* chunk loop: everything that is genuinely I/O (checkpoint/restart,
ledger records, eval, dashboards). Everything that used to be per-round host
work — batch staging, cohort selection, straggler deadlines — now runs
*inside* the compiled program: ``core/rounds.build_multi_round`` scans
``fl.rounds_per_launch`` rounds per launch over partition tensors staged on
device once in ``scaffold()``, so the host only wakes up at chunk
boundaries. ``rounds_per_launch=1`` recovers the per-round host loop, and by
the driver's determinism contract both chunkings produce bitwise-identical
params for the same seed.

``fl.placement`` selects the client placement: "spatial" (clients vmapped
across the grid, the seed default) or "temporal" (one client at a time uses
the whole mesh); "auto" resolves to spatial.

``fl.mode`` selects the execution mode: "sync" (round-synchronous, above) or
"async" (event-driven FedAsync/FedBuff over the virtual clock — see
core/async_rounds.py). The async path shares this chunk loop shape: a
"round" is ``events_per_round`` server events, ``rounds_per_launch`` rounds
compile into one event scan, and checkpoint/ledger/eval/logging reuse the
same chunk-boundary plumbing.

ProcessPhase: 0=init 1=local-learning 2=aggregation (paper §2.3).
NodeStage:    0=not-ready 1=ready-for-job 2=ready-with-dataset
              3=busy 4=waiting/complete.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint import ckpt as ckpt_mod
from repro.core import determinism
from repro.core.blockchain import param_digest
from repro.core.kvstore import KVStore
from repro.core.rounds import build_multi_round, init_state
from repro.data.pipeline import stage_partitions
from repro.metrics.logger import PerformanceLogger
from repro.sharding.axes import AxisCtx


@dataclasses.dataclass
class Executor:
    job: Any                              # core.jobs.Job
    ctx: AxisCtx = AxisCtx()
    ckpt_dir: Optional[str] = None
    logger: Optional[PerformanceLogger] = None
    eval_fn: Optional[Callable] = None    # (params) -> dict of metrics

    def __post_init__(self):
        self.kv = KVStore()
        self.logger = self.logger or PerformanceLogger(run_name=self.job.name)
        fl = self.job.fl
        self.placement = fl.placement if fl.placement != "auto" else "spatial"
        self.mode = fl.mode
        if self.mode == "async":
            from repro.core.async_rounds import build_async_multi
            # async "round" = events_per_round server events: one FedBuff
            # buffer flush, or (FedAsync) one arrival per client on average.
            self.events_per_round = (fl.async_buffer if fl.async_buffer > 1
                                     else fl.n_clients)
            self._multi = build_async_multi(self.job.model,
                                            self.job.strategy, fl)
        elif self.mode == "sync":
            self._multi = build_multi_round(
                self.job.model, self.job.strategy, fl,
                cfg=getattr(self.job.model, "cfg", None),
                placement=self.placement, fault=self.job.fault)
        else:
            raise ValueError(f"unknown mode {self.mode!r} "
                             "(want 'sync' or 'async')")
        self._programs = {}               # scan length -> jitted program

    def _round_program(self, n_rounds: int):
        """Jitted n_rounds-launch; at most two lengths ever compile (the
        chunk size and one remainder)."""
        if n_rounds not in self._programs:
            self._programs[n_rounds] = jax.jit(
                lambda s, staged, root, start, n=n_rounds:
                self._multi(self.ctx, s, staged, root, start, n))
        return self._programs[n_rounds]

    def _event_program(self, n_events: int):
        """Jitted async launch scanning ``n_events`` server events."""
        key = ("async", n_events)
        if key not in self._programs:
            self._programs[key] = jax.jit(
                lambda s, staged, sched, root, start, n=n_events:
                self._multi(self.ctx, s, staged, sched, root, start, n))
        return self._programs[key]

    def _build_schedule(self, n_rounds: int):
        """Precompute + stage the virtual-clock event schedule (async)."""
        import numpy as _np

        from repro.core.async_rounds import async_init_state
        from repro.runtime.clock import ClientSystemModel, build_schedule

        fl = self.job.fl
        csm = self.job.fault
        if not isinstance(csm, ClientSystemModel):
            csm = ClientSystemModel(**dataclasses.asdict(csm))
        self.schedule = build_schedule(
            csm, fl.n_clients, n_rounds * self.events_per_round,
            _np.asarray(self.staged["len"], _np.float32),
            buffer_size=fl.async_buffer,
            staleness_exponent=fl.staleness_exponent,
            max_staleness=fl.max_staleness,
            concurrency=fl.async_concurrency)
        self.sched_dev = self.schedule.device_arrays()
        if "hist" not in self.state:
            self.state = async_init_state(self.state, self.schedule.ring)

    # -- Alg. 1 lines 1-15: scaffold ------------------------------------
    def scaffold(self):
        fl = self.job.fl
        self.kv.set_process_phase(0)
        nodes = [f"client_{i}" for i in range(fl.n_clients)]
        for n in nodes:                      # "DownloadJobConfig <- True"
            self.kv.set_node_stage(n, 1)
        x, y, parts = self.job.dataset.distribute_into_chunks(
            fl.partition, fl.n_clients, fl.dirichlet_alpha)
        self.data = (x, y, parts)   # host view, kept for eval_fn consumers
        # "DownloadDataset": the one-time device staging of the full client
        # partition — the round loop never touches host data after this.
        self.staged = stage_partitions(x, y, parts)
        for n in nodes:
            self.kv.set_node_stage(n, 2)
        self.nodes = nodes
        key = determinism.root_key(fl.seed)
        self.state = init_state(self.job.model, self.job.strategy, fl, key,
                                n_clients_local=fl.n_clients)
        if self.mode == "async":
            self._build_schedule(fl.rounds)
        self.round_idx = 0
        # restart path (fault tolerance): resume from the newest manifest
        if self.ckpt_dir:
            last = ckpt_mod.latest_round(self.ckpt_dir)
            if last is not None:
                self.state, extra = ckpt_mod.restore(
                    self.ckpt_dir, last, self.state)
                self.round_idx = extra["next_round"]
        return self

    # -- Alg. 1 lines 16-57: chunked round loop ---------------------------
    def run(self, rounds: Optional[int] = None):
        if self.mode == "async":
            return self._run_async(rounds)
        fl = self.job.fl
        rounds = rounds or fl.rounds
        root = determinism.root_key(fl.seed)
        chunk = max(fl.rounds_per_launch, 1)
        while self.round_idx < rounds:
            start = self.round_idx
            n = min(chunk, rounds - start)
            # phase 1+2 (cohort selection, local learning, aggregation) all
            # happen inside the compiled multi-round program
            self.kv.set_process_phase(1)
            for node in self.nodes:
                self.kv.set_node_stage(node, 3)
            self.kv.set_process_phase(2)
            t0 = time.time()
            state, metrics = self._round_program(n)(
                self.state, self.staged, root, start)
            state = jax.block_until_ready(state)
            dt = time.time() - t0
            self.state = state
            stacked = {k: np.asarray(v) for k, v in metrics.items()}
            rows = [dict({k: float(v[i]) for k, v in stacked.items()},
                         round_s=dt / n) for i in range(n)]
            self._finish_chunk(start, n, rows)
        return self.state, self.logger

    def _finish_chunk(self, start: int, n: int, rows):
        """Chunk-boundary host I/O, shared by the sync and async loops:
        ledger record, eval (merged into the last round's row), logging,
        round-index advance, checkpoint-cadence save."""
        fl = self.job.fl
        for node in self.nodes:
            self.kv.set_node_stage(node, 4)
        last = start + n - 1
        if self.job.ledger is not None:
            dig = param_digest(self.state["params"])
            self.job.ledger.record_global(last, self.state["params"])
            self.kv.publish(f"global_digest/{last}", dig)
        if self.eval_fn is not None:
            rows[-1].update({k: float(v) for k, v in
                             self.eval_fn(self.state["params"]).items()})
        for i in range(n):
            self.logger.log_round(start + i, **rows[i])
        self.round_idx += n
        # save when this chunk crossed a checkpoint_every multiple (the
        # cadence survives chunk sizes that don't divide it)
        if self.ckpt_dir and fl.checkpoint_every and \
                start // fl.checkpoint_every != \
                self.round_idx // fl.checkpoint_every:
            ckpt_mod.save(self.ckpt_dir, self.round_idx, self.state,
                          extra={"next_round": self.round_idx},
                          async_write=False)

    # -- async: chunked event loop ----------------------------------------
    def _run_async(self, rounds: Optional[int] = None):
        """Event-driven execution. A "round" is ``events_per_round`` server
        events; the chunk loop, and all chunk-boundary host I/O, are the
        sync loop's — only the compiled program differs (an event scan
        instead of a round scan)."""
        fl = self.job.fl
        rounds = rounds or fl.rounds
        root = determinism.root_key(fl.seed)
        chunk = max(fl.rounds_per_launch, 1)
        epr = self.events_per_round
        if rounds * epr > len(self.schedule):
            # Horizon grew past the scaffolded schedule. Regenerating is
            # only safe before any event ran (or for FedAsync, which has no
            # buffer groups): a FedBuff group left open at the old horizon
            # gets renormalized coefficients once the longer horizon closes
            # it, which would silently de-normalize contributions already
            # folded into the carried accumulator.
            if self.round_idx > 0 and fl.async_buffer > 1:
                raise RuntimeError(
                    f"async run asked for {rounds} rounds mid-flight but "
                    f"the schedule covers {len(self.schedule) // epr}; "
                    "scaffold with a larger fl.rounds (or resume from a "
                    "checkpoint) instead of growing a FedBuff run in place")
            self._build_schedule(rounds)
        while self.round_idx < rounds:
            start = self.round_idx
            n = min(chunk, rounds - start)
            n_ev = n * epr
            self.kv.set_process_phase(1)
            for node in self.nodes:
                self.kv.set_node_stage(node, 3)
            self.kv.set_process_phase(2)
            t0 = time.time()
            state, metrics = self._event_program(n_ev)(
                self.state, self.staged, self.sched_dev, root, start * epr)
            state = jax.block_until_ready(state)
            dt = time.time() - t0
            self.state = state
            stacked = {k: np.asarray(v).reshape(n, epr)
                       for k, v in metrics.items()}
            rows = [{"loss": float(stacked["loss"][i].mean()),
                     "staleness": float(stacked["staleness"][i].mean()),
                     "applied": float(stacked["applied"][i].sum()),
                     "round_s": dt / n,
                     "events_per_s": n_ev / max(dt, 1e-9)}
                    for i in range(n)]
            self._finish_chunk(start, n, rows)
        return self.state, self.logger
