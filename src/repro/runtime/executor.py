"""Host-level FL executor — the faithful rendering of paper Algorithm 1.

The Logic Controller's ProcessPhase x NodeStage machine survives here as the
*host* chunk loop: everything that is genuinely I/O (checkpoint/restart,
ledger records, eval, dashboards). Everything that used to be per-round host
work — batch staging, cohort selection, straggler deadlines — now runs
*inside* the compiled program: ``core/rounds.build_multi_round`` scans
``fl.rounds_per_launch`` rounds per launch over partition tensors staged on
device once in ``scaffold()``, so the host only wakes up at chunk
boundaries. ``rounds_per_launch=1`` recovers the per-round host loop, and by
the driver's determinism contract both chunkings produce bitwise-identical
params for the same seed.

``fl.placement`` selects the client placement: "spatial" (clients vmapped
across the grid, the seed default) or "temporal" (one client at a time uses
the whole mesh); "auto" resolves to spatial.

``fl.mode`` selects the execution mode: "sync" (round-synchronous, above) or
"async" (event-driven FedAsync/FedBuff over the virtual clock — see
core/async_rounds.py). The async path shares this chunk loop shape: a
"round" is ``events_per_round`` server events, ``rounds_per_launch`` rounds
compile into one event scan, and checkpoint/ledger/eval/logging reuse the
same chunk-boundary plumbing.

ProcessPhase: 0=init 1=local-learning 2=aggregation (paper §2.3).
NodeStage:    0=not-ready 1=ready-for-job 2=ready-with-dataset
              3=busy 4=waiting/complete.
"""
from __future__ import annotations

import dataclasses
import pathlib
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt as ckpt_mod
from repro.configs.base import SWEEPABLE_SCALARS
from repro.core import determinism
from repro.core.blockchain import param_digest
from repro.core.kvstore import KVStore
from repro.core.plan import resolve_placement
from repro.core.probes import (ASYNC_REDUCE, PROBE_NAMES, ProbeSpec,
                               ProbeTable, buffer_occupancy, staleness_hist)
from repro.core.rounds import build_multi_round, build_ragged_multi, init_state
from repro.data.pipeline import make_slab_stager, slab_nbytes, stage_partitions
from repro.kernels import ops as kernel_ops
from repro.metrics.logger import PerformanceLogger, host_usage
from repro.sharding.axes import AxisCtx
from repro.telemetry import comms as comms_mod
from repro.telemetry.recorder import FlightRecorder


@dataclasses.dataclass
class Executor:
    job: Any                              # core.jobs.Job
    ctx: AxisCtx = AxisCtx()
    ckpt_dir: Optional[str] = None
    logger: Optional[PerformanceLogger] = None
    eval_fn: Optional[Callable] = None    # (params) -> dict of metrics
    # Flight recorder (repro/telemetry): host-side span tracing + launch
    # counters over the chunk-boundary seams. None -> built from the job's
    # ``telemetry:`` section (a no-op recorder when the section is absent);
    # the planner passes one shared recorder with per-bucket tracks.
    recorder: Optional[FlightRecorder] = None
    telemetry_track: str = "run"

    def __post_init__(self):
        self.kv = KVStore()
        self.logger = self.logger or PerformanceLogger(run_name=self.job.name)
        if self.recorder is None:
            self.recorder = FlightRecorder.from_job(
                self.job, fallback_dir=getattr(self, "out_dir", None))
        self._launches = 0                # launch ordinal (profile_chunks)
        # Round probe plane (core/probes.py): a ``probes:`` job section
        # compiles read-only per-round diagnostics into the scans; drained
        # at chunk boundaries into counter tracks + probes.csv.
        self.probes_spec = ProbeSpec.from_job(self.job)
        self.probe_rows = []              # tidy per-round probe rows
        self._probe_flushed = 0
        self._probe_table = None
        self._pending_probes = None       # launch stash for the drain
        # Comms observatory (telemetry/comms.py): a ``comms:`` job section
        # turns on host-side wire-traffic accounting + the simulated
        # wall-clock; accountants are built at scaffold (they need the
        # param template). Pure host bookkeeping — bitwise comms on == off.
        self.comms_spec = comms_mod.CommsSpec.from_job(self.job)
        self.comms_rows = []              # tidy per-round comms rows
        self._comms = None                # per-lane LaneComms accountants
        self._comms_flushed = 0
        self._comms_table = None
        self._pending_comms = None        # launch stash for the drain
        self._digest_blocks = 0           # async ledger-digest cadence
        # per-program FLOPs/bytes off the lowered computation (telemetry
        # report's program table); ``cost_analysis: false`` opts out
        t = (getattr(self.job, "raw", None) or {}).get("telemetry") or {}
        self._cost_enabled = bool(t.get("cost_analysis", True))
        self._cost_seen = set()
        self._last_program = None
        fl = self.job.fl
        from repro.core.jobs import validate_cohort
        validate_cohort(fl)
        # single source of truth with core/plan.py's program signatures:
        # a drift here would bucket lanes whose compiled programs differ
        self.placement = resolve_placement(fl)
        self.mode = fl.mode
        # ragged client plane (fl.max_cohort > 0): launches consume per-chunk
        # cohort slabs from a data/pipeline stager instead of a resident
        # root — n_clients/cohort never reach the trace
        self.ragged = fl.max_cohort > 0
        self.stager = None
        if self.mode == "async":
            from repro.core.async_rounds import build_async_multi
            # async "round" = events_per_round server events: one FedBuff
            # buffer flush, or (FedAsync) one arrival per client on average.
            self.events_per_round = (fl.async_buffer if fl.async_buffer > 1
                                     else fl.n_clients)
            self._multi = build_async_multi(
                self.job.model, self.job.strategy, fl,
                probes=self.probes_spec.enabled,
                on_divergence=self.probes_spec.on_divergence,
                ragged=self.ragged)
        elif self.mode == "sync":
            if self.ragged:
                self._multi = build_ragged_multi(
                    self.job.model, self.job.strategy, fl,
                    placement=self.placement,
                    probes=self.probes_spec.enabled,
                    on_divergence=self.probes_spec.on_divergence)
            else:
                self._multi = build_multi_round(
                    self.job.model, self.job.strategy, fl,
                    cfg=getattr(self.job.model, "cfg", None),
                    placement=self.placement, fault=self.job.fault,
                    probes=self.probes_spec.enabled,
                    on_divergence=self.probes_spec.on_divergence)
        else:
            raise ValueError(f"unknown mode {self.mode!r} "
                             "(want 'sync' or 'async')")
        self._programs = {}               # scan length -> jitted program
        # Sweepable scalars are threaded into the compiled programs as
        # *runtime* values even for a single run: XLA compiles a scalar-
        # multiply chain differently for a compile-time constant than for a
        # runtime value, so this is what makes a campaign lane (where the
        # scalars are vmapped (S,) arrays) bitwise-identical to this
        # single-run path (threefry + elementwise math are vmap-invariant).
        fl = self.job.fl
        self.hyper = {"seed": jnp.int32(fl.seed)}
        self.hyper.update({k: jnp.float32(getattr(fl, k))
                           for k in SWEEPABLE_SCALARS if k != "seed"})

    def compiled_programs(self) -> int:
        """How many distinct XLA programs this executor has compiled —
        the planner's bucket-count contract ("a 24-point grid with 4
        signatures compiles 4 programs") is asserted against this. Reads
        the jit caches when the jax version exposes them; falls back to
        one per (program, scan length) entry."""
        total = 0
        for prog in self._programs.values():
            size = getattr(prog, "_cache_size", None)
            try:
                total += int(size()) if callable(size) else 1
            except Exception:
                total += 1
        return total

    def _round_program(self, n_rounds: int):
        """Jitted n_rounds-launch; at most two lengths ever compile (the
        chunk size and one remainder)."""
        if n_rounds not in self._programs:
            self._programs[n_rounds] = jax.jit(
                lambda s, staged, root, hyper, start, n=n_rounds:
                self._multi(self.ctx, s, staged, root, start, n, hyper))
        return self._programs[n_rounds]

    def _event_program(self, n_events: int):
        """Jitted async launch scanning ``n_events`` server events."""
        key = ("async", n_events)
        if key not in self._programs:
            self._programs[key] = jax.jit(
                lambda s, staged, sched, root, hyper, start, n=n_events:
                self._multi(self.ctx, s, staged, sched, root, start, n,
                            hyper))
        return self._programs[key]

    def _build_schedule(self, n_rounds: int):
        """Precompute + stage the virtual-clock event schedule (async)."""
        import numpy as _np

        from repro.core.async_rounds import async_init_state
        from repro.runtime.clock import ClientSystemModel, build_schedule

        fl = self.job.fl
        csm = self.job.fault
        if not isinstance(csm, ClientSystemModel):
            csm = ClientSystemModel(**dataclasses.asdict(csm))
        lens = (_np.asarray(self.stager.lens, _np.float32) if self.ragged
                else _np.asarray(self.staged["len"], _np.float32))
        self.schedule = build_schedule(
            csm, fl.n_clients, n_rounds * self.events_per_round,
            lens,
            buffer_size=fl.async_buffer,
            staleness_exponent=fl.staleness_exponent,
            max_staleness=fl.max_staleness,
            concurrency=fl.async_concurrency)
        self.sched_dev = self.schedule.device_arrays()
        # buffer-occupancy probe stream: a pure function of the schedule's
        # accept/apply bits, so it is precomputed host-side once
        self._occupancy = buffer_occupancy(self.schedule.accept,
                                           self.schedule.apply)
        if "hist" not in self.state:
            self.state = async_init_state(self.state, self.schedule.ring,
                                          fl, self.job.strategy)

    # -- Alg. 1 lines 1-15: scaffold ------------------------------------
    def scaffold(self):
        """One scaffold sequence for single runs and campaigns; the
        campaign overrides only the staging/init/restore hooks. Each hook
        runs under a flight-recorder span (stage/init/schedule/restore are
        exactly the wall-clock sinks the report attributes outside the
        launch loop)."""
        fl = self.job.fl
        rec, track = self.recorder, self.telemetry_track
        with rec.span("scaffold", track=track):
            self.kv.set_process_phase(0)
            self.nodes = [f"client_{i}" for i in range(fl.n_clients)]
            for n in self.nodes:             # "DownloadJobConfig <- True"
                self.kv.set_node_stage(n, 1)
            with rec.span("stage_data", track=track):
                self._stage_data()
            for n in self.nodes:
                self.kv.set_node_stage(n, 2)
            with rec.span("init_state", track=track):
                self._init_state()
            if self.mode == "async":
                with rec.span("build_schedule", track=track):
                    self._build_schedule(fl.rounds)
            self.round_idx = 0
            with rec.span("restore", track=track):
                self._maybe_restore()
            self._post_restore()
            self._comms_setup()
            self._record_plane_bytes()
        return self

    def _comms_setup(self):
        """Build the comms accountant (campaigns override: one per lane).
        Needs the scaffolded param template; cumulative counters start at
        zero, so a checkpoint resume accounts only post-resume rounds."""
        if not self.comms_spec.enabled:
            return
        from repro.core.netmodel import shape_template
        fl = self.job.fl
        # decentralized params carry a per-client leading dim; the byte
        # model prices ONE model's exchange
        tpl = shape_template(self.state["params"],
                             strip_leading=fl.topology == "decentralized")
        self._comms = [comms_mod.LaneComms(
            fl=fl, csm=self.job.fault, template=tpl,
            pods=self.comms_spec.pods)]

    def _record_plane_bytes(self):
        """Counter: device bytes staged per plane (data idx/len + roots,
        async schedules, traced scalars). Computed from shapes/dtypes —
        nothing is pulled back from device."""
        rec = self.recorder
        if not rec.enabled:
            return

        def nbytes(tree):
            return int(sum(leaf.size * leaf.dtype.itemsize
                           for leaf in jax.tree.leaves(tree)))

        if self.ragged:
            # ragged mode stages no population up front: device_bytes is the
            # resident stager's root (0 when streaming); the per-chunk slab
            # working set lands as its own counter at every launch
            values = {"data_plane": int(self.stager.device_bytes),
                      "scalar_plane": nbytes(self.hyper)}
        else:
            values = {"data_plane": nbytes(self.staged),
                      "scalar_plane": nbytes(self.hyper)}
        if getattr(self, "sched_dev", None) is not None:
            values["schedule_plane"] = nbytes(self.sched_dev)
        rec.counter("staged_bytes", track=self.telemetry_track, **values)

    def _record_slab_bytes(self, slab):
        """Per-chunk ``staged_bytes`` counter for ragged launches: the
        slab working set this launch actually staged, the stager's running
        peak, and what full residency would have cost — the streaming
        plane's bounded-memory claim, measurable from telemetry.jsonl."""
        rec = self.recorder
        if not rec.enabled:
            return
        rec.counter("staged_bytes", track=self.telemetry_track,
                    slab=slab_nbytes(slab),
                    peak_slab=int(self.stager.peak_slab_bytes),
                    resident_equiv=int(self.stager.resident_bytes))

    def _stage_data(self):
        """"DownloadDataset": the one-time device staging of the full client
        partition — the round loop never touches host data after this.
        Ragged mode builds a slab stager instead: staging happens per chunk
        (resident gather or streaming host->device copies)."""
        fl = self.job.fl
        if self.ragged:
            self.stager = make_slab_stager(self.job.dataset, fl,
                                           self.job.fault)
            self.staged = None
            self.data = getattr(self.stager, "data", None)
            return
        x, y, parts = self.job.dataset.distribute_into_chunks(
            fl.partition, fl.n_clients, fl.dirichlet_alpha)
        self.data = (x, y, parts)   # host view, kept for eval_fn consumers
        self.staged = stage_partitions(x, y, parts)

    def _init_state(self):
        fl = self.job.fl
        # built once: the chunk loop passes it to every launch
        self.root = determinism.root_key(fl.seed)
        self.state = init_state(self.job.model, self.job.strategy, fl,
                                self.root, n_clients_local=fl.n_clients)

    def _post_restore(self):
        """Hook after a checkpoint restore (campaigns re-adopt their
        results table here)."""

    def _maybe_restore(self):
        """Restart path (fault tolerance): resume from the newest manifest."""
        if self.ckpt_dir:
            last = ckpt_mod.latest_round(self.ckpt_dir)
            if last is not None:
                self.state, extra = ckpt_mod.restore(
                    self.ckpt_dir, last, self.state)
                self.round_idx = extra["next_round"]

    # -- Alg. 1 lines 16-57: chunked round loop ---------------------------
    def run(self, rounds: Optional[int] = None):
        """Run (or continue) the chunked round loop up to ``rounds``."""
        rounds = rounds or self.job.fl.rounds
        self._run_total = rounds      # sizes the ragged stager's prefetch
        if self.mode == "async":
            self._check_async_horizon(rounds)
            return self._chunk_loop(rounds, self._launch_async)
        return self._chunk_loop(rounds, self._launch_sync)

    def _chunk_loop(self, rounds: int, launch):
        """The shared chunked round loop (sync, async, and campaign
        execution all use it): per chunk, phase bookkeeping, one compiled
        launch (``launch(start, n) -> rows``, one metrics row per round),
        then chunk-boundary host I/O (``_finish_chunk``). With telemetry
        on, the loop runs inside its own quant-agg counter scope (runs in
        one process can't bleed routing counts into each other) and the
        run-level totals land as counters at the end."""
        rec = self.recorder
        if not rec.enabled:
            return self._chunk_loop_inner(rounds, launch)
        with kernel_ops.quant_agg_scope() as qframe:
            out = self._chunk_loop_inner(rounds, launch)
        rec.counter("quant_agg", track=self.telemetry_track,
                    calls=qframe["calls"],
                    batched_fallbacks=qframe["batched_fallbacks"])
        rec.counter("programs", track=self.telemetry_track,
                    compiled=self.compiled_programs())
        for values in self._comms_summaries():
            rec.counter("comms_total", track=self.telemetry_track, **values)
        rec.flush()
        return out

    def _chunk_loop_inner(self, rounds: int, launch):
        chunk = max(self.job.fl.rounds_per_launch, 1)
        rec, track = self.recorder, self.telemetry_track
        while self.round_idx < rounds:
            start = self.round_idx
            n = min(chunk, rounds - start)
            # phase 1+2 (cohort selection, local learning, aggregation) all
            # happen inside the compiled program
            self.kv.set_process_phase(1)
            for node in self.nodes:
                self.kv.set_node_stage(node, 3)
            self.kv.set_process_phase(2)
            with rec.span("chunk", track=track, start=start, n=n):
                rows = self._recorded_launch(launch, start, n)
                with rec.span("finish_chunk", track=track):
                    self._finish_chunk(start, n, rows)
        return self.state, self.logger

    def _recorded_launch(self, launch, start: int, n: int):
        """One compiled launch under a "launch" span carrying the per-launch
        telemetry: compile-count delta (jit-cache reading — a launch that
        grew the cache is a cold/compile launch), quant-agg routing delta,
        and the driver-specific attrs (lane occupancy for campaigns); host
        RSS/CPU and lane counters sample after the launch. ``profile()``
        wraps the launch in a jax.profiler capture when the job's
        ``telemetry.profile_chunks`` lists this launch ordinal."""
        rec = self.recorder
        if not rec.enabled:
            return launch(start, n)
        ordinal = self._launches
        self._launches += 1
        progs0 = self.compiled_programs()
        calls0 = kernel_ops.quant_agg_stats()["calls"]
        with rec.profile(ordinal), \
                rec.span("launch", track=self.telemetry_track,
                         mode=self.mode, start=start, n=n,
                         ordinal=ordinal) as sp:
            rows = launch(start, n)
            sp.attrs.update(
                compile_delta=self.compiled_programs() - progs0,
                quant_agg_traces=(kernel_ops.quant_agg_stats()["calls"]
                                  - calls0),
                **self._telemetry_attrs())
        rec.counter("host", track=self.telemetry_track, **host_usage())
        self._record_lane_telemetry()
        self._record_program_cost(sp)
        self._drain_probe_counters(sp._t0, rec._now_us())
        self._drain_comms_counters(sp._t0, rec._now_us())
        return rows

    def _record_program_cost(self, sp):
        """FLOPs/bytes per compiled program off ``Lowered.cost_analysis()``
        (lowering only retraces — no second backend compile), recorded once
        per program key on its compile launch; the telemetry report's
        program table picks the counter up."""
        stash, self._last_program = self._last_program, None
        if stash is None or not sp.attrs.get("compile_delta"):
            return
        key, prog, args = stash
        if key in self._cost_seen:
            return
        self._cost_seen.add(key)
        try:
            cost = prog.lower(*args).cost_analysis()
            if isinstance(cost, (list, tuple)):
                cost = cost[0] if cost else {}
            values = {
                "flops": float(cost.get("flops", 0.0)),
                "bytes_accessed": float(cost.get("bytes accessed", 0.0))}
        except Exception:
            return                 # cost analysis is backend-best-effort
        self.recorder.counter("program_cost", track=self.telemetry_track,
                              program=str(key), **values)

    # -- probe drain (core/probes.py) -------------------------------------
    def _capture_probes(self, start: int, n: int, probes, extra=None,
                        hists=None):
        """Stash a launch's per-round probe matrices: tidy rows buffer now
        (flushed to probes.csv at the chunk boundary), counter samples at
        ``_drain_probe_counters`` (back-dated across the launch span —
        probes are device values the host first sees at the boundary)."""
        if probes is None:
            return
        # one (n, P) matrix off the device, one tolist(): everything
        # downstream (rows, counter series, json/csv encoding) works on
        # native python floats — per-element numpy scalar extraction and
        # per-probe transfers dominate at chunk=1
        a = np.asarray(probes)
        cols = {name: a[..., j].tolist()
                for j, name in enumerate(PROBE_NAMES)}
        if extra:
            cols.update({k: np.asarray(v).tolist()
                         for k, v in extra.items()})
        items = sorted(cols.items())
        for i in range(n):
            row = {"round": start + i}
            row.update((k, col[i]) for k, col in items)
            self.probe_rows.append(row)
        self._pending_probes = (start, n, cols, hists or {})

    def _drain_probe_counters(self, t0_us: int, t1_us: int):
        """Perfetto "C" tracks: one ``probe:<name>`` counter per probe (the
        campaign override emits one series per alive lane), per-round
        samples interpolated across the launch span they were computed
        inside; histogram counters land at the span end."""
        pend, self._pending_probes = self._pending_probes, None
        if pend is None or not self.recorder.enabled:
            return
        start, n, mats, hists = pend
        rec, track = self.recorder, self.telemetry_track
        for i in range(n):
            t = int(t0_us + (t1_us - t0_us) * (i + 1) / n)
            for name, m in mats.items():
                rec.counter(f"probe:{name}", track=track, t_us=t,
                            **self._probe_series(m, i))
        for name, values in hists.items():
            rec.counter(name, track=track, t_us=t1_us, **values)

    def _probe_series(self, m, i: int) -> dict:
        """Counter series for round ``i`` (campaigns: one per alive lane)."""
        return {"value": m[i]}

    def _reduce_async_probes(self, probes, n: int):
        """(..., n_events, P) per-event probe plane -> (..., n, P)
        per-round values. The reductions are fixed per probe
        (core/probes.ASYNC_REDUCE) and rounds are fixed event windows, so
        any chunking yields the same per-round stream."""
        if probes is None:
            return None
        epr = self.events_per_round
        a = np.asarray(probes)
        a = a.reshape(a.shape[:-2] + (n, epr, a.shape[-1]))
        out = np.empty(a.shape[:-3] + (n, a.shape[-1]), np.float32)
        for j, name in enumerate(PROBE_NAMES):
            red = ASYNC_REDUCE.get(name, "mean")
            out[..., j] = getattr(a[..., j], red)(axis=-1)
        return out

    def _async_probe_extras(self, start: int, n: int):
        """Host-side async probe columns: per-round mean buffer occupancy
        (precomputed from the schedule's accept/apply stream)."""
        epr = self.events_per_round
        occ = self._occupancy[start * epr:(start + n) * epr]
        return {"buffer_occ": occ.reshape(n, epr).mean(-1)}

    # -- comms drain (telemetry/comms.py) ---------------------------------
    def _account_comms(self, start: int, n: int):
        """Advance the comms accountant over this launch's rounds: tidy
        rows buffer now (flushed to comms.csv at the chunk boundary),
        counter samples at ``_drain_comms_counters``. Returns the per-round
        column dict (the launch merges ``sim_time_s``/``cum_bytes`` into
        its result rows) or None with comms off."""
        if self._comms is None:
            return None
        lane = self._comms[0]
        if self.mode == "async":
            cols = lane.async_rounds(start, n, self.schedule,
                                     self.events_per_round)
        else:
            cols = lane.sync_rounds(start, n)
        items = sorted(cols.items())
        for i in range(n):
            row = {"round": start + i}
            row.update((k, float(col[i])) for k, col in items)
            self.comms_rows.append(row)
        self._pending_comms = (start, n, cols)
        return cols

    def _merge_comms(self, rows, cols, n: int):
        """Join the simulated-time / cumulative-byte columns onto the
        launch's result rows — eval metrics merged into the same rows then
        plot directly as time-to-accuracy / bytes-to-accuracy curves."""
        if cols:
            for i in range(n):
                rows[i].update({k: float(cols[k][i])
                                for k in comms_mod.RESULT_COLUMNS})
        return rows

    def _drain_comms_counters(self, t0_us: int, t1_us: int):
        """Perfetto "C" tracks: cumulative per-direction bytes + the
        virtual-time track (campaigns: one series per alive lane),
        back-dated across the launch span like the probe counters."""
        pend, self._pending_comms = self._pending_comms, None
        if pend is None or not self.recorder.enabled:
            return
        start, n, cols = pend
        rec, track = self.recorder, self.telemetry_track
        for i in range(n):
            t = int(t0_us + (t1_us - t0_us) * (i + 1) / n)
            for name in comms_mod.COUNTER_COLUMNS:
                rec.counter(f"comms:{name}", track=track, t_us=t,
                            **self._comms_series(cols[name], i))

    def _comms_series(self, m, i: int) -> dict:
        """Counter series for round ``i`` (campaigns: one per alive lane)."""
        return {"value": float(m[i])}

    def _comms_summaries(self) -> list:
        """Run-level ``comms_total`` counter payloads (campaigns: one per
        lane, tagged with its index)."""
        if self._comms is None:
            return []
        return [self._comms[0].summary()]

    def _telemetry_attrs(self) -> dict:
        """Driver-specific launch-span attrs (campaigns: lane occupancy)."""
        return {}

    def _record_lane_telemetry(self):
        """Post-launch counters hook (campaigns: per-shard lane alive)."""

    def _prefetch_next(self, start: int, n: int) -> None:
        """Kick the stager's double buffer for the next chunk, so its host
        gather + host->device copy overlap this launch's device time."""
        chunk = max(self.job.fl.rounds_per_launch, 1)
        total = getattr(self, "_run_total", self.job.fl.rounds)
        nxt = min(chunk, total - (start + n))
        if nxt > 0:
            self.stager.prefetch(start + n, nxt)

    def _launch_sync(self, start: int, n: int):
        t0 = time.time()
        prog = self._round_program(n)
        if self.ragged:
            staged = self.stager.slab(start, n)
            self._record_slab_bytes(staged)
            self._prefetch_next(start, n)
        else:
            staged = self.staged
        args = (self.state, staged, self.root, self.hyper, start)
        if self.recorder.enabled and self._cost_enabled:
            self._last_program = (n, prog, args)
        state, metrics = prog(*args)
        self.state = jax.block_until_ready(state)
        dt = time.time() - t0
        self._capture_probes(start, n, metrics.pop("probes", None))
        cols = self._account_comms(start, n)
        stacked = {k: np.asarray(v) for k, v in metrics.items()}
        return self._merge_comms(
            [dict({k: float(v[i]) for k, v in stacked.items()},
                  round_s=dt / n) for i in range(n)], cols, n)

    def _launch_async(self, start: int, n: int):
        """An async "round" is ``events_per_round`` server events; only the
        compiled program differs from the sync launch (an event scan
        instead of a round scan)."""
        epr = self.events_per_round
        n_ev = n * epr
        t0 = time.time()
        prog = self._event_program(n_ev)
        e0 = start * epr
        if self.ragged:
            staged = self.stager.event_slab(
                self.schedule.client[e0:e0 + n_ev], tag=(e0, n_ev))
            self._record_slab_bytes(staged)
            chunk_ev = max(self.job.fl.rounds_per_launch, 1) * epr
            total_ev = getattr(self, "_run_total", self.job.fl.rounds) * epr
            nxt = min(chunk_ev, total_ev - (e0 + n_ev))
            if nxt > 0:
                self.stager.prefetch_events(
                    self.schedule.client[e0 + n_ev:e0 + n_ev + nxt],
                    tag=(e0 + n_ev, nxt))
        else:
            staged = self.staged
        args = (self.state, staged, self.sched_dev, self.root,
                self.hyper, e0)
        if self.recorder.enabled and self._cost_enabled:
            self._last_program = (("async", n_ev), prog, args)
        state, metrics = prog(*args)
        self.state = jax.block_until_ready(state)
        dt = time.time() - t0
        probes = self._reduce_async_probes(metrics.pop("probes", None), n)
        stacked = {k: np.asarray(v).reshape(n, epr)
                   for k, v in metrics.items()}
        if probes is not None:
            self._capture_probes(
                start, n, probes, extra=self._async_probe_extras(start, n),
                hists={"probe:staleness_hist": staleness_hist(
                    stacked["staleness"], self.job.fl.max_staleness)})
        cols = self._account_comms(start, n)
        # virtual arrival time at each round window's last event: async
        # curves plot against virtual time even with comms accounting off
        vt = self.schedule.vtime
        return self._merge_comms(
            [{"loss": float(stacked["loss"][i].mean()),
              "staleness": float(stacked["staleness"][i].mean()),
              "applied": float(stacked["applied"][i].sum()),
              "vtime": float(vt[(start + i + 1) * epr - 1]),
              "round_s": dt / n,
              "events_per_s": n_ev / max(dt, 1e-9)}
             for i in range(n)], cols, n)

    def _check_async_horizon(self, rounds: int):
        """Horizon grew past the scaffolded schedule? Regenerating is only
        safe before any event ran (or for FedAsync, which has no buffer
        groups): a FedBuff group left open at the old horizon gets
        renormalized coefficients once the longer horizon closes it, which
        would silently de-normalize contributions already folded into the
        carried accumulator."""
        fl = self.job.fl
        epr = self.events_per_round
        if rounds * epr > len(self.schedule):
            if self.round_idx > 0 and fl.async_buffer > 1:
                raise RuntimeError(
                    f"async run asked for {rounds} rounds mid-flight but "
                    f"the schedule covers {len(self.schedule) // epr}; "
                    "scaffold with a larger fl.rounds (or resume from a "
                    "checkpoint) instead of growing a FedBuff run in place")
            self._build_schedule(rounds)

    def _finish_chunk(self, start: int, n: int, rows):
        """Chunk-boundary host I/O, shared by the sync/async/campaign loops:
        ledger record, eval (merged into the last round's row), logging,
        round-index advance, checkpoint-cadence save."""
        fl = self.job.fl
        rec, track = self.recorder, self.telemetry_track
        for node in self.nodes:
            self.kv.set_node_stage(node, 4)
        last = start + n - 1
        if self.job.ledger is not None:
            with rec.span("ledger", track=track):
                self._ledger_record(last)
        if self.eval_fn is not None:
            with rec.span("eval", track=track):
                self._merge_eval(rows)
        else:
            self._merge_eval(rows)
        for i in range(n):
            self.logger.log_round(start + i, **rows[i])
        if self.probes_spec.enabled and \
                len(self.probe_rows) > self._probe_flushed:
            with rec.span("probe_flush", track=track):
                self._flush_probes()
        if self.comms_spec.enabled and \
                len(self.comms_rows) > self._comms_flushed:
            with rec.span("comms_flush", track=track):
                self._flush_comms()
        if self.mode == "async" and fl.digest_every_events > 0 and \
                self.job.ledger is not None:
            self._digest_cadence(start, n, last)
        self.round_idx += n
        # save when this chunk crossed a checkpoint_every multiple (the
        # cadence survives chunk sizes that don't divide it)
        if self.ckpt_dir and fl.checkpoint_every and \
                start // fl.checkpoint_every != \
                self.round_idx // fl.checkpoint_every:
            with rec.span("checkpoint_save", track=track,
                          round=self.round_idx):
                ckpt_mod.save(self.ckpt_dir, self.round_idx, self.state,
                              extra=self._ckpt_extra(), async_write=False)

    def _ckpt_extra(self) -> dict:
        """Checkpoint manifest extras (campaigns add the lane count so a
        resume against a different sweep grid fails loudly)."""
        return {"next_round": self.round_idx}

    # -- probes.csv --------------------------------------------------------
    def _probe_lead_columns(self):
        return ["round"]

    def _probe_path(self) -> Optional[pathlib.Path]:
        """Where probes.csv lands: the ``probes.out_dir`` knob, else the
        telemetry out_dir, else the executor's own out_dir/ckpt_dir (rows
        stay memory-only when none is set). Non-default tracks (planner
        buckets) suffix the filename so a shared dir cannot collide."""
        spec = self.probes_spec
        out = spec.out_dir or \
            (self.recorder.out_dir if self.recorder.enabled else None) or \
            getattr(self, "out_dir", None) or self.ckpt_dir
        if out is None:
            return None
        name = ("probes.csv" if self.telemetry_track == "run"
                else f"probes_{self.telemetry_track}.csv")
        return pathlib.Path(out) / name

    def _flush_probes(self):
        """Append the rows buffered since the last boundary to probes.csv
        (tidy, keyed like campaign.csv); ``self.probe_rows`` keeps the full
        in-memory view either way."""
        new = self.probe_rows[self._probe_flushed:]
        self._probe_flushed = len(self.probe_rows)
        if not new:
            return
        if self._probe_table is None:
            path = self._probe_path()
            if path is None:
                return
            self._probe_table = ProbeTable(path, self._probe_lead_columns())
        self._probe_table.flush(new)

    # -- comms.csv ---------------------------------------------------------
    def _comms_lead_columns(self):
        return ["round"]

    def _comms_path(self) -> Optional[pathlib.Path]:
        """Where comms.csv lands: the ``comms.out_dir`` knob, else the
        telemetry out_dir, else the executor's own out_dir/ckpt_dir (rows
        stay memory-only when none is set); planner buckets suffix the
        track like probes.csv."""
        out = self.comms_spec.out_dir or \
            (self.recorder.out_dir if self.recorder.enabled else None) or \
            getattr(self, "out_dir", None) or self.ckpt_dir
        if out is None:
            return None
        name = ("comms.csv" if self.telemetry_track == "run"
                else f"comms_{self.telemetry_track}.csv")
        return pathlib.Path(out) / name

    def _flush_comms(self):
        """Append the rows buffered since the last boundary to comms.csv
        (tidy, keyed like campaign.csv); ``self.comms_rows`` keeps the full
        in-memory view either way. The column set is fixed
        (netmodel.COMMS_COLUMNS), so ProbeTable's append-only writer fits."""
        new = self.comms_rows[self._comms_flushed:]
        self._comms_flushed = len(self.comms_rows)
        if not new:
            return
        if self._comms_table is None:
            path = self._comms_path()
            if path is None:
                return
            self._comms_table = ProbeTable(path, self._comms_lead_columns())
        self._comms_table.flush(new)

    # -- async ledger-digest cadence (ROADMAP carried item) ----------------
    def _digest_cadence(self, start: int, n: int, last: int):
        """Emit one ledger digest block per ``digest_every_events`` mark the
        finished chunk crossed (evaluated at chunk boundaries — the block
        digests the boundary state, so the block *count* is chunking-
        invariant). Recorded as a "digest" span + cumulative counter so
        digest cost shows in the telemetry report."""
        rec, track = self.recorder, self.telemetry_track
        epr = self.events_per_round
        d = self.job.fl.digest_every_events
        e0, e1 = start * epr, (start + n) * epr
        marks = range((e0 // d + 1) * d, e1 + 1, d)
        if not len(marks):
            return
        with rec.span("digest", track=track, events=e1, blocks=len(marks)):
            for m in marks:
                self._digest_record(m, last)
        rec.counter("digest", track=track, blocks=self._digest_blocks)

    def _digest_record(self, event_mark: int, last: int):
        """One digest block (campaigns override: one per alive lane). The
        block carries the virtual arrival time of its event mark, so ledger
        rows line up with the async virtual-time axis."""
        self._digest_blocks += 1
        self.job.ledger.append(
            last, "async_digest",
            {"event": int(event_mark),
             "vtime": float(self.schedule.vtime[event_mark - 1]),
             "digest": param_digest(self.state["params"])})

    def _ledger_record(self, last: int):
        """Ledger hook at the chunk boundary (campaigns override: one block
        per trajectory lane, so per-run provenance stays auditable)."""
        dig = param_digest(self.state["params"])
        self.job.ledger.record_global(last, self.state["params"])
        self.kv.publish(f"global_digest/{last}", dig)

    def _merge_eval(self, rows):
        """Eval hook at the chunk boundary (campaigns override: per-lane)."""
        if self.eval_fn is not None:
            rows[-1].update({k: float(v) for k, v in
                             self.eval_fn(self.state["params"]).items()})
