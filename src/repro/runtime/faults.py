"""Fault injection + straggler simulation (paper Alg. 1 timeout() semantics,
scaled to 1000+-node thinking).

The host executor asks this module, per round, which cohort members respond
in time. Deterministic given the seed — so fault-tolerance tests can assert
bitwise-reproducible recovery.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class FaultModel:
    drop_prob: float = 0.0        # client fails mid-round
    straggler_prob: float = 0.0   # client exceeds the deadline
    straggler_slowdown: float = 4.0
    worker_fail_prob: float = 0.0
    seed: int = 0

    def round_outcome(self, round_idx: int, client_ids):
        """Returns (alive_mask, sim_durations). Durations ~ lognormal with
        stragglers inflated; the executor keeps the first-K by duration."""
        rng = np.random.RandomState(self.seed * 1_000_003 + round_idx)
        n = len(client_ids)
        alive = rng.rand(n) >= self.drop_prob
        dur = rng.lognormal(mean=0.0, sigma=0.25, size=n)
        stragglers = rng.rand(n) < self.straggler_prob
        dur = np.where(stragglers, dur * self.straggler_slowdown, dur)
        return alive, dur


def select_cohort(fault: FaultModel, round_idx: int, client_ids,
                  target: int, overprovision: float = 1.0):
    """Over-provisioned cohort with deadline-drop (straggler mitigation):
    sample ceil(target*overprovision) clients, keep the ``target`` fastest
    alive ones; if fewer than target survive, keep the survivors and
    re-normalize weights (unbiased under random failures)."""
    want = int(np.ceil(target * overprovision))
    rng = np.random.RandomState(0xC0047 + round_idx)
    pool = rng.choice(client_ids, size=min(want, len(client_ids)),
                      replace=False)
    alive, dur = fault.round_outcome(round_idx, pool)
    surv = pool[alive]
    dur = dur[alive]
    order = np.argsort(dur)
    kept = surv[order[:target]]
    return np.sort(kept)
