"""Fault injection + straggler simulation (paper Alg. 1 timeout() semantics,
scaled to 1000+-node thinking).

The deadline-drop semantics live in ``cohort_mask`` — a *jittable* weight
mask, so the device-resident multi-round driver (core/rounds.py
``build_multi_round``) can select cohorts inside the compiled program with
no host round-trips. The host-side ``select_cohort`` is a thin wrapper over
the same function and therefore agrees with the in-program mask bit-for-bit
(regression-tested in tests/test_driver.py). Deterministic given the seed —
so fault-tolerance tests can assert bitwise-reproducible recovery.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import determinism


@dataclasses.dataclass(frozen=True)
class FaultModel:
    drop_prob: float = 0.0        # client fails mid-round
    straggler_prob: float = 0.0   # client exceeds the deadline
    straggler_slowdown: float = 4.0
    worker_fail_prob: float = 0.0
    seed: int = 0

    def round_outcome(self, round_idx: int, client_ids):
        """Returns (alive_mask, sim_durations) as numpy arrays. Durations are
        lognormal with stragglers inflated; the deadline keeps the first-K."""
        _, k_out = jax.random.split(determinism.cohort_key(self.seed,
                                                           round_idx))
        alive, dur = _outcome(self, k_out, len(client_ids))
        return np.asarray(alive), np.asarray(dur)


def _outcome(fault: FaultModel, key, n: int):
    """Jittable (alive, duration) draw for ``n`` clients."""
    k_alive, k_dur, k_strag = jax.random.split(key, 3)
    alive = jax.random.uniform(k_alive, (n,)) >= fault.drop_prob
    dur = jnp.exp(0.25 * jax.random.normal(k_dur, (n,)))
    strag = jax.random.uniform(k_strag, (n,)) < fault.straggler_prob
    dur = jnp.where(strag, dur * fault.straggler_slowdown, dur)
    return alive, dur


def cohort_mask(fault: FaultModel, round_idx, n_clients: int, target: int,
                overprovision: float = 1.0):
    """Over-provisioned cohort with deadline-drop as a float32 weight mask.

    Jittable: ``round_idx`` may be a traced scalar (it is, inside the
    multi-round scan). Samples ceil(target*overprovision) clients without
    replacement, drops the dead, keeps the ``target`` fastest survivors; if
    fewer than target survive, the survivors are kept and the aggregator's
    weight normalization makes the drop unbiased under random failures.
    Returns shape (n_clients,): 1.0 for kept clients, 0.0 otherwise.
    """
    want = int(min(math.ceil(target * overprovision), n_clients))
    key = determinism.cohort_key(fault.seed, round_idx)
    k_pool, k_out = jax.random.split(key)
    perm = jax.random.permutation(k_pool, n_clients)
    in_pool = jnp.zeros((n_clients,), bool).at[perm[:want]].set(True)
    alive, dur = _outcome(fault, k_out, n_clients)
    eligible = in_pool & alive
    dur = jnp.where(eligible, dur, jnp.inf)
    rank = jnp.argsort(jnp.argsort(dur))   # rank of each client by duration
    kept = eligible & (rank < target)
    return kept.astype(jnp.float32)


def select_cohort(fault: FaultModel, round_idx: int, client_ids,
                  target: int, overprovision: float = 1.0):
    """Host view of ``cohort_mask``: the sorted kept client ids."""
    client_ids = np.asarray(client_ids)
    mask = np.asarray(cohort_mask(fault, round_idx, len(client_ids),
                                  int(target), overprovision))
    return np.sort(client_ids[mask > 0])
