"""Deterministic virtual clock for event-driven asynchronous FL.

The sync driver models client heterogeneity only as a per-round deadline
drop (``faults.cohort_mask``). Async execution needs the *time axis* itself:
each client trains continuously, completions arrive at the server out of
order, and the server reacts per arrival (FedAsync) or per K arrivals
(FedBuff). This module renders that as a **host-precomputed event
schedule**: a discrete-event simulation over a virtual clock, driven by
``ClientSystemModel`` (the ``FaultModel`` extended with the client *system*
dimension — per-client speed, per-task lognormal jitter, availability).

The schedule is plain numpy — client id, task index, staleness, ring slots,
aggregation coefficients per server event — and is staged on device once, so
the event loop in ``core/async_rounds.py`` can compile as a ``lax.scan``
over events with no host round-trips. Everything is keyed by the seed:

- durations/availability come from per-task Philox streams keyed by
  ``(seed, field, task_index)``, so the schedule for E events is a prefix of
  the schedule for E' > E events (regeneration cannot rewrite history);
- ties on the virtual clock break by client id, and all arrivals at one
  timestamp are processed before any client re-dispatches — that convention
  is what makes "FedBuff with buffer == cohort and equal client speeds"
  collapse to synchronous FedAvg (the identity test in tests/test_async.py).

Staleness bookkeeping: the server version bumps at each *apply* event; a
task's staleness is (version at arrival) - (version at dispatch). Stale
snapshots live in a ring buffer of the last ``max_staleness + 1`` versions
(``ring``); arrivals older than ``max_staleness`` are rejected (coefficient
0), which also guarantees every in-ring read is valid.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq

import jax.numpy as jnp
import numpy as np

from repro.runtime.faults import FaultModel

_F32 = np.float32

# Philox stream tags (second 64-bit key word, high half). _TAG_LINK is the
# comms observatory's bandwidth-tier stream (core/netmodel.py): a NEW tag,
# so adding a LinkModel never re-deals the rate/jitter/straggler/avail
# columns — existing schedules stay prefix-stable link knobs on or off.
_TAG_RATE, _TAG_JITTER, _TAG_STRAGGLER, _TAG_AVAIL, _TAG_LINK = 1, 2, 3, 4, 5


@dataclasses.dataclass(frozen=True)
class ClientSystemModel(FaultModel):
    """``FaultModel`` grown into a client *system* model (speed + arrival).

    Reuses the fault fields the sync path already draws from —
    ``straggler_prob`` / ``straggler_slowdown`` inflate task durations,
    ``drop_prob`` folds into availability — and adds the async-only knobs:

    - ``mean_duration``: virtual-time cost of one local-training task;
    - ``duration_sigma``: per-task lognormal jitter (the sync ``_outcome``
      draw uses sigma 0.25; 0 makes every task of a client take equal time);
    - ``rate_spread``: persistent per-client lognormal speed spread
      (device heterogeneity, not per-task noise);
    - ``availability``: probability a finished task's update is usable
      (an unavailable arrival is rejected: zero weight, no buffer slot).

    The link fields are the **LinkModel** (core/netmodel.py): per-client
    up/down bandwidth tiers + per-transfer latency, consumed only by the
    host-side comms accounting plane — the event schedule never reads them,
    so two runs differing only in link knobs share bitwise-identical
    schedules (regression-tested in tests/test_comms.py):

    - ``up_mbps`` / ``down_mbps``: top-tier client bandwidth (Mbit/s of
      *virtual* time, the same unit as ``mean_duration``);
    - ``link_tiers``: number of bandwidth classes; each client draws its
      tier from the ``_TAG_LINK`` Philox stream (1 = homogeneous);
    - ``link_tier_factor``: bandwidth multiplier per tier below the top
      (tier t gets ``factor**t``);
    - ``latency_s``: fixed per-transfer latency (virtual seconds).
    """
    mean_duration: float = 1.0
    duration_sigma: float = 0.25
    rate_spread: float = 0.0
    availability: float = 1.0
    up_mbps: float = 100.0
    down_mbps: float = 400.0
    link_tiers: int = 1
    link_tier_factor: float = 0.5
    latency_s: float = 0.01


def _column(seed: int, tag: int, task: int, draw, n: int):
    """One deterministic draw of ``n`` values for task index ``task``.

    A fresh Philox generator per (seed, tag, task) column keeps the schedule
    prefix-stable in the number of events: extending the horizon only adds
    columns, it never re-deals earlier ones."""
    key = np.array([np.uint64(seed & 0xFFFFFFFF),
                    np.uint64((tag << 32) | (task & 0xFFFFFFFF))],
                   dtype=np.uint64)
    return draw(np.random.Generator(np.random.Philox(key=key)), n)


def client_rates(csm: ClientSystemModel, n_clients: int) -> np.ndarray:
    """Persistent per-client speed multipliers (lognormal, mean-ish 1)."""
    z = _column(csm.seed, _TAG_RATE, 0,
                lambda g, n: g.standard_normal(n), n_clients)
    return np.exp(csm.rate_spread * z).astype(_F32)


def _dur_column(csm: ClientSystemModel, rate: np.ndarray,
                t: int) -> np.ndarray:
    """Durations of every client's task ``t``: rate * lognormal * straggler.

    Degenerate knobs skip their Philox column entirely — the output is
    identical (``sigma == 0`` zeroes the exponent, ``straggler_prob == 0``
    makes the where-mask all-False regardless of ``u``) and per-(tag, task)
    keying means an unconsumed column never shifts any other draw. Philox
    construction is the host cost of the comms plane's makespan replay, so
    the common no-straggler case pays one column, not two."""
    n = rate.shape[0]
    if csm.duration_sigma != 0.0:
        z = _column(csm.seed, _TAG_JITTER, t,
                    lambda g, m: g.standard_normal(m), n)
        d = csm.mean_duration * rate * np.exp(csm.duration_sigma * z)
    else:
        d = csm.mean_duration * rate
    if csm.straggler_prob <= 0.0:
        return np.asarray(d, _F32)
    u = _column(csm.seed, _TAG_STRAGGLER, t, lambda g, m: g.random(m), n)
    return np.where(u < csm.straggler_prob,
                    d * csm.straggler_slowdown, d).astype(_F32)


def _ok_column(csm: ClientSystemModel, n_clients: int, t: int) -> np.ndarray:
    """Usability of every client's task ``t`` (availability x not-dropped)."""
    p_ok = float(csm.availability) * (1.0 - float(csm.drop_prob))
    u = _column(csm.seed, _TAG_AVAIL, t, lambda g, m: g.random(m), n_clients)
    return u < p_ok


class _Columns:
    """Task columns drawn lazily as the simulation consumes task indices.

    Memory/host-time scale with the *deepest task index actually reached*
    (~E/C for balanced speeds), not with the E x C worst case; per-task
    Philox streams keep the values independent of how far we draw."""

    def __init__(self, draw):
        self._draw = draw
        self._cols: list = []

    def __call__(self, c: int, t: int):
        while len(self._cols) <= t:
            self._cols.append(self._draw(len(self._cols)))
        return self._cols[t][c]


def task_durations(csm: ClientSystemModel, n_clients: int,
                   n_tasks: int) -> np.ndarray:
    """(C, T) virtual durations: rate * per-task lognormal * straggler."""
    rate = client_rates(csm, n_clients)
    return np.stack([_dur_column(csm, rate, t) for t in range(n_tasks)], 1)


def task_usable(csm: ClientSystemModel, n_clients: int,
                n_tasks: int) -> np.ndarray:
    """(C, T) bool: does the arrival of task t of client c carry weight."""
    return np.stack([_ok_column(csm, n_clients, t) for t in range(n_tasks)],
                    1)


@dataclasses.dataclass(frozen=True)
class EventSchedule:
    """One server event per completed client task, in virtual-time order."""
    client: np.ndarray      # (E,) int32  client arriving at event e
    task: np.ndarray        # (E,) int32  that client's task index (its k-th)
    staleness: np.ndarray   # (E,) int32  server versions elapsed in flight
    accept: np.ndarray      # (E,) bool   arrival usable (fresh + available)
    apply: np.ndarray       # (E,) bool   server update fires at this event
    read_slot: np.ndarray   # (E,) int32  ring slot of the task's start params
    write_slot: np.ndarray  # (E,) int32  ring slot the apply writes (else 0)
    coeff: np.ndarray       # (E,) f32    staleness-weighted agg coefficient
    vtime: np.ndarray       # (E,) f64    virtual arrival time
    ring: int               # param-history ring size (max_staleness + 1)
    n_versions: int         # server versions produced over the horizon

    def __len__(self) -> int:
        return int(self.client.shape[0])

    def device_arrays(self) -> dict:
        """The per-event arrays the compiled event scan consumes."""
        return {
            "client": jnp.asarray(self.client),
            "task": jnp.asarray(self.task),
            "staleness": jnp.asarray(self.staleness),
            # accept gates the packed FedBuff buffer-slot write: a rejected
            # arrival must not claim a slot (coeff == 0 can't distinguish it
            # from an accepted zero-weight client)
            "accept": jnp.asarray(self.accept),
            "apply": jnp.asarray(self.apply),
            "read_slot": jnp.asarray(self.read_slot),
            "write_slot": jnp.asarray(self.write_slot),
            "coeff": jnp.asarray(self.coeff),
        }


def build_schedule(csm: ClientSystemModel, n_clients: int, n_events: int,
                   weights, *, buffer_size: int = 0,
                   staleness_exponent: float = 0.0, max_staleness: int = 8,
                   concurrency: int = 0) -> EventSchedule:
    """Simulate the virtual clock and emit the first ``n_events`` arrivals.

    ``weights`` are the per-client aggregation weights (partition sizes).
    ``buffer_size`` <= 1 selects FedAsync semantics (every accepted arrival
    applies; ``coeff`` is the pure staleness weight); K > 1 selects FedBuff
    (apply every K accepted arrivals; ``coeff`` is the staleness-and-size
    weighted share of the buffer group, so the grouped update is the
    weighted mean of its deltas). ``concurrency`` caps clients in flight
    (0 = all clients train continuously).

    Convention: all arrivals at one virtual timestamp are processed (in
    client-id order) before any finished client re-dispatches, so a task
    dispatched "at" an apply sees the post-apply version.
    """
    E = int(n_events)
    C = int(n_clients)
    # degenerate inputs fail loudly, naming the field: E <= 0 used to
    # return a silently-empty schedule and C == 0 crashed the event loop
    # with a bare IndexError off the empty dispatch heap
    if E <= 0:
        raise ValueError(f"build_schedule needs n_events > 0, got "
                         f"{n_events} (fl.rounds * events_per_round must "
                         "be positive)")
    if C <= 0:
        raise ValueError(f"build_schedule needs n_clients > 0, got "
                         f"{n_clients} (no clients to dispatch)")
    K = max(int(buffer_size), 1)
    M = C if concurrency <= 0 else min(int(concurrency), C)
    ring = int(max_staleness) + 1
    w = np.asarray(weights, _F32).reshape(-1)
    if w.shape[0] != C:
        raise ValueError(f"weights shape {w.shape} != n_clients {C}")

    rate = client_rates(csm, C)
    dur = _Columns(lambda t: _dur_column(csm, rate, t))
    usable = _Columns(lambda t: _ok_column(csm, C, t))

    client = np.zeros(E, np.int32)
    task = np.zeros(E, np.int32)
    staleness = np.zeros(E, np.int32)
    accept = np.zeros(E, bool)
    apply = np.zeros(E, bool)
    read_slot = np.zeros(E, np.int32)
    write_slot = np.zeros(E, np.int32)
    aw = np.zeros(E, _F32)            # staleness-weight * client weight
    den = np.ones(E, _F32)            # buffer-group normalizer (FedBuff)
    alpha_arr = np.zeros(E, _F32)     # pure staleness weight (FedAsync)
    vtime = np.zeros(E, np.float64)

    heap: list = []                   # (finish_time, client)
    waiting = collections.deque(range(M, C))
    start_version = np.zeros(C, np.int64)   # version seen at dispatch
    done = np.zeros(C, np.int64)            # completed tasks per client
    for c in range(M):
        heapq.heappush(heap, (float(dur(c, 0)), c))

    version = 0
    buf_n = 0
    buf_den = _F32(0.0)
    group: list = []                  # event ids of the open buffer group
    e = 0
    while e < E:
        t, _ = heap[0]
        arrivals = []
        while heap and heap[0][0] == t:
            arrivals.append(heapq.heappop(heap)[1])
        for c in arrivals:            # heap pops ties in client-id order
            if e >= E:
                break
            k = int(done[c])
            s = version - int(start_version[c])
            ok = bool(usable(c, k)) and s <= int(max_staleness)
            alpha = _F32((1.0 + s) ** (-float(staleness_exponent))) \
                if ok else _F32(0.0)
            client[e] = c
            task[e] = k
            staleness[e] = s
            accept[e] = ok
            read_slot[e] = int(start_version[c]) % ring
            aw[e] = alpha * w[c]
            alpha_arr[e] = alpha
            vtime[e] = t
            if ok:
                buf_n += 1
                buf_den = _F32(buf_den + aw[e])
                group.append(e)
                if buf_n >= K:
                    apply[e] = True
                    version += 1
                    write_slot[e] = version % ring
                    den[group] = max(buf_den, _F32(1e-12))
                    buf_n, buf_den, group = 0, _F32(0.0), []
            done[c] = k + 1
            e += 1
        # re-dispatch only after the whole timestamp group is processed
        for c in arrivals:
            waiting.append(c)
        while len(heap) < M and waiting:
            c = waiting.popleft()
            start_version[c] = version
            heapq.heappush(heap, (t + float(dur(c, int(done[c]))), c))
    if group:                         # trailing open group: never applied
        den[group] = max(buf_den, _F32(1e-12))

    if K > 1:
        coeff = (aw / den).astype(_F32)
    else:
        coeff = alpha_arr
    return EventSchedule(client=client, task=task, staleness=staleness,
                         accept=accept, apply=apply, read_slot=read_slot,
                         write_slot=write_slot, coeff=coeff, vtime=vtime,
                         ring=ring, n_versions=version)
