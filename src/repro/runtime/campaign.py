"""Campaign executor — S sweep trajectories in ONE compiled program.

The paper's headline is streamlined benchmarking of "a plethora" of FL
experiments from job configs; a multi-seed, multi-alpha comparison used to
cost S sequential runs of the Executor. Here the *trajectory* becomes a
batch axis: ``core/sweeps.py`` expands the job's ``sweep:`` section into S
per-trajectory configs split into a data plane (staged partitions stacked to
``(S, C, Lmax)``; async schedules stacked to ``(S, E)``) and a scalar plane
(traced ``(S,)`` knob arrays threaded through ``rounds.bind_hyper``), and
``CampaignExecutor`` wraps the *same* sync round scan / async event scan the
single-run Executor compiles in an outer ``jax.vmap``. One launch advances
all S trajectories; the host chunk loop, checkpoint/ledger/eval boundary
I/O, and the bitwise chunking contract are inherited from ``Executor``.

Determinism contract (tests/test_sweeps.py): lane ``s`` of a campaign is
**bitwise identical** to an independent single run of the s-th expanded
config — threefry draws are vectorization-invariant (the same argument
``gather_client_batches`` relies on), the stacked staging pads are
unobservable, and the scalar plane only swaps Python floats for
equal-valued traced f32s. Chunked == unchunked also holds under the sweep
axis, so campaigns checkpoint/resume like single runs (the stacked state is
one pytree).

Results land in a tidy table keyed by sweep coordinates (one row per
trajectory per round) — ``campaign.csv`` always, ``campaign.parquet`` when
pandas+pyarrow are importable; ``benchmarks/figures.campaign_curves`` draws
multi-seed mean±band curves from it.
"""
from __future__ import annotations

import csv
import dataclasses
import pathlib
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sweeps
from repro.core.blockchain import param_digest
from repro.core.jobs import make_dataset, make_fault
from repro.core.rounds import init_state
from repro.data.pipeline import stage_partitions_stacked
from repro.runtime.executor import Executor

_INT_COLS = ("seed", "traj", "round")


def read_results(csv_path) -> list:
    """Read a campaign.csv back into tidy rows (numbers, not strings);
    blank cells (eval columns off the chunk tails) are dropped. The single
    parser for the campaign table — resume and figures both use it."""
    with open(csv_path, newline="") as f:
        return [{k: (int(float(v)) if k in _INT_COLS else float(v))
                 for k, v in row.items() if v != ""}
                for row in csv.DictReader(f)]


@dataclasses.dataclass
class CampaignExecutor(Executor):
    """Executor over the sweep axis: same compiled programs, outer vmap.

    ``job`` must carry a ``sweep:`` section (``job.sweep``). ``eval_fn``
    keeps the single-run signature ``params -> dict`` and is applied per
    trajectory lane. ``out_dir`` (if set) receives the results table at the
    end of ``run()``.
    """
    out_dir: Optional[str] = None

    def __post_init__(self):
        if self.job.sweep is None:
            raise ValueError("CampaignExecutor needs a job with a sweep: "
                             "section (see core/sweeps.py for the axes)")
        self.spec = self.job.sweep
        self.coords = self.spec.coords()
        self.fls = sweeps.expand(self.job.fl, self.spec)
        self.S = len(self.fls)
        self.results = []              # tidy rows: coords + traj/round/metrics
        self._tail_rows = []           # last-round row per trajectory
        super().__post_init__()

    # -- scaffold hooks: stacked staging + vmapped init --------------------
    def _stage_data(self):
        """Data plane: restage per distinct (seed, partition, alpha);
        scalar-only sweeps share one triple (stacking still duplicates on
        device, which is what keeps every lane's gather identical to a
        single run). Also builds the scalar plane + per-trajectory roots.
        ``self.data`` is the list of per-trajectory (x, y, parts) host
        views (eval_fn consumers index it by lane)."""
        cfg = getattr(self.job.model, "cfg", None)
        cache, trajs = {}, []
        for fl_s in self.fls:
            k = (fl_s.seed, fl_s.partition, fl_s.dirichlet_alpha)
            if k not in cache:
                ds = make_dataset(self.job.raw, fl_s, cfg)
                cache[k] = ds.distribute_into_chunks(
                    fl_s.partition, fl_s.n_clients, fl_s.dirichlet_alpha)
            trajs.append(cache[k])
        self.trajectories = trajs
        self.data = trajs
        self.staged = stage_partitions_stacked(trajs)
        self.roots = sweeps.root_keys(self.fls)
        self.hyper = sweeps.scalar_plane(self.fls)

    def _init_state(self):
        fl = self.job.fl
        self.state = jax.vmap(
            lambda key: init_state(self.job.model, self.job.strategy, fl,
                                   key, n_clients_local=fl.n_clients))(
            self.roots)

    def _post_restore(self):
        """Resume path: re-adopt the pre-restart rows (the table is
        rewritten at every chunk boundary, so a completed chunk is never
        lost) — without this a resumed campaign would silently write a
        table missing every pre-resume round."""
        if self.round_idx > 0 and self.out_dir:
            prior = pathlib.Path(self.out_dir) / "campaign.csv"
            if prior.exists():
                self.results = [r for r in read_results(prior)
                                if r["round"] < self.round_idx]

    def _build_schedule(self, n_rounds: int):
        """Per-trajectory virtual-clock schedules (seed and
        staleness_exponent are sweepable), stacked to (S, E) on device."""
        from repro.core.async_rounds import async_init_state
        from repro.runtime.clock import build_schedule

        fl = self.job.fl
        lens = np.asarray(self.staged["len"], np.float32)   # (S, C)
        self.schedules = [
            build_schedule(
                make_fault(self.job.raw, fl_s), fl.n_clients,
                n_rounds * self.events_per_round, lens[s],
                buffer_size=fl.async_buffer,
                staleness_exponent=fl_s.staleness_exponent,
                max_staleness=fl.max_staleness,
                concurrency=fl.async_concurrency)
            for s, fl_s in enumerate(self.fls)]
        self.schedule = self.schedules[0]       # horizon checks read len()
        devs = [s.device_arrays() for s in self.schedules]
        self.sched_dev = {k: jnp.stack([d[k] for d in devs]) for k in devs[0]}
        if "hist" not in self.state:
            ring = self.schedules[0].ring
            self.state = jax.vmap(
                lambda st: async_init_state(st, ring))(self.state)

    # -- compiled programs: the Executor's, under an outer vmap ------------
    def _round_program(self, n_rounds: int):
        if n_rounds not in self._programs:
            def launch(s, staged, roots, hyper, start, n=n_rounds):
                return jax.vmap(
                    lambda st, sg, rt, hp:
                    self._multi(self.ctx, st, sg, rt, start, n, hp))(
                    s, staged, roots, hyper)
            self._programs[n_rounds] = jax.jit(launch)
        return self._programs[n_rounds]

    def _event_program(self, n_events: int):
        key = ("async", n_events)
        if key not in self._programs:
            def launch(s, staged, sched, roots, hyper, start, n=n_events):
                return jax.vmap(
                    lambda st, sg, sd, rt, hp:
                    self._multi(self.ctx, st, sg, sd, rt, start, n, hp))(
                    s, staged, sched, roots, hyper)
            self._programs[key] = jax.jit(launch)
        return self._programs[key]

    # -- chunk launches (the inherited _chunk_loop drives these) ----------
    def _launch_sync(self, start: int, n: int):
        t0 = time.time()
        state, metrics = self._round_program(n)(
            self.state, self.staged, self.roots, self.hyper, start)
        self.state = jax.block_until_ready(state)
        dt = time.time() - t0
        stacked = {k: np.asarray(v) for k, v in metrics.items()}  # (S, n)
        return self._table_rows(stacked, start, n, dt)

    def _launch_async(self, start: int, n: int):
        epr = self.events_per_round
        n_ev = n * epr
        t0 = time.time()
        state, metrics = self._event_program(n_ev)(
            self.state, self.staged, self.sched_dev, self.roots, self.hyper,
            start * epr)
        self.state = jax.block_until_ready(state)
        dt = time.time() - t0
        ev = {k: np.asarray(v).reshape(self.S, n, epr)
              for k, v in metrics.items()}
        stacked = {"loss": ev["loss"].mean(-1),
                   "staleness": ev["staleness"].mean(-1),
                   "applied": ev["applied"].sum(-1)}
        return self._table_rows(stacked, start, n, dt)

    def _table_rows(self, stacked, start: int, n: int, dt: float):
        """Append per-(trajectory, round) rows to the tidy results table;
        return per-round rows (trajectory means) for the inherited logger."""
        self._tail_rows = []
        for s in range(self.S):
            for i in range(n):
                row = {**self.coords[s], "traj": s, "round": start + i,
                       **{k: float(v[s, i]) for k, v in stacked.items()},
                       "round_s": dt / n}
                self.results.append(row)
                if i == n - 1:
                    self._tail_rows.append(row)
        return [dict({k: float(v[:, i].mean()) for k, v in stacked.items()},
                     round_s=dt / n) for i in range(n)]

    def _ledger_record(self, last: int):
        """One ledger block per trajectory lane: the digest of lane ``s``
        equals the digest of the s-th single run (bitwise contract), so
        per-run provenance stays auditable — a digest of the stacked pytree
        would certify parameters no run ever produced."""
        for s in range(self.S):
            params_s = jax.tree.map(lambda t: t[s], self.state["params"])
            self.job.ledger.record_global(last, params_s)
            self.kv.publish(f"global_digest/{last}/traj{s}",
                            param_digest(params_s))

    def _merge_eval(self, rows):
        """Per-lane eval at the chunk boundary: merged into each
        trajectory's tail row of the results table, means into the logger."""
        if self.eval_fn is None:
            return
        agg = {}
        for s, row in enumerate(self._tail_rows):
            params_s = jax.tree.map(lambda t: t[s], self.state["params"])
            ev = {k: float(v) for k, v in self.eval_fn(params_s).items()}
            row.update(ev)
            for k, v in ev.items():
                agg.setdefault(k, []).append(v)
        rows[-1].update({k: float(np.mean(v)) for k, v in agg.items()})

    # -- results table -----------------------------------------------------
    def _finish_chunk(self, start: int, n: int, rows):
        super()._finish_chunk(start, n, rows)
        # rewrite the table at every chunk boundary (it is small): a crash
        # loses at most the open chunk, and resume re-adopts what is there
        if self.out_dir:
            self.write_results()

    def run(self, rounds: Optional[int] = None):
        state, logger = super().run(rounds)
        if self.out_dir:
            self.write_results()
        return state, logger

    def trajectory_params(self, s: int):
        """Lane ``s``'s params (bitwise the s-th single run's)."""
        return jax.tree.map(lambda t: np.asarray(t[s]),
                            self.state["params"])

    def write_results(self, out_dir=None):
        """Write the tidy results table: ``campaign.csv`` (always) and
        ``campaign.parquet`` (when pandas+pyarrow are importable). Schema:
        one row per (trajectory, round) — sweep coordinate columns in axis
        order, then ``traj``, ``round``, metric columns."""
        out = pathlib.Path(out_dir or self.out_dir or ".")
        out.mkdir(parents=True, exist_ok=True)
        lead = [*self.spec.names, "traj", "round"]
        keys = lead + sorted({k for r in self.results for k in r} - set(lead))
        csv_path = out / "campaign.csv"
        with open(csv_path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=keys)
            w.writeheader()
            w.writerows(self.results)
        try:
            import pandas as pd
            pd.DataFrame(self.results, columns=keys).to_parquet(
                out / "campaign.parquet")
        except Exception:
            pass                       # CSV is the portable artifact
        return csv_path
