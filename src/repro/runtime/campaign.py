"""Campaign executor — S sweep trajectories in ONE compiled program.

The paper's headline is streamlined benchmarking of "a plethora" of FL
experiments from job configs; a multi-seed, multi-alpha comparison used to
cost S sequential runs of the Executor. Here the *trajectory* becomes a
batch axis: ``core/sweeps.py`` expands the job's ``sweep:`` section into S
per-trajectory configs split into a data plane (unique root datasets staged
once and shared via an offset-index indirection — scalar-only sweeps no
longer duplicate the dataset S times; per-lane ``idx``/``len`` planes carry
the ``(S,)`` dim), a schedule plane (async schedules stacked to ``(S, E)``)
and a scalar plane (traced ``(S,)`` knob arrays threaded through
``rounds.bind_hyper``), and ``CampaignExecutor`` wraps the *same* sync round
scan / async event scan the single-run Executor compiles in an outer
``jax.vmap``. One launch advances all S trajectories; the host chunk loop,
checkpoint/ledger/eval boundary I/O, and the bitwise chunking contract are
inherited from ``Executor``.

One executor serves one *program signature* (``core/plan.py``): every lane
must trace to the job's compiled program. Heterogeneous sweeps (categorical
axes — strategy/topology/placement/mode/async_buffer) go through the
planner, which buckets lanes by signature and instantiates one
``CampaignExecutor`` per bucket via the ``lanes`` override
(``runtime/scheduler.py::PlanExecutor``).

The lane scheduler's per-lane ``alive`` mask threads into the compiled
program as a runtime value alongside the scalar plane: a dropped lane's
state freezes (``rounds.freeze_unless``) with **no recompilation**, its
rows stop landing in the results table, and its ledger blocks stop.

``lane_devices = n`` shards the sweep axis over an n-device lane mesh
(``launch/mesh.lane_mesh``): lanes are embarrassingly parallel, so the
leading (S,) dim of every plane — data ``idx``/``len``, schedules, scalars,
alive mask, stacked model state — carries a
``jax.sharding.NamedSharding`` over ``lanes`` while the concatenated data
roots and unique schedules replicate, and the *same* compiled vmap program
partitions into n zero-collective shards. S pads up to a multiple of n
with dead lanes (``alive = 0`` from launch 1, so padding is the same
maskwork as a scheduler drop — ``freeze_unless``, no recompilation) and
padded lanes never reach the results table, the ledger, or eval. The
schedule plane also dedups (satellite): async lanes sharing
(seed, system model, staleness knobs) share ONE (E,) schedule on device,
indexed per lane like the data roots.

Determinism contract (tests/test_sweeps.py, tests/test_plan.py): lane ``s``
of a campaign is **bitwise identical** to an independent single run of the
s-th expanded config — threefry draws are vectorization-invariant (the same
argument ``gather_client_batches`` relies on), the offset gather relocates
identical bytes, the stacked pads are unobservable, the scalar plane only
swaps Python floats for equal-valued traced f32s, and the alive select is
the bitwise identity for alive lanes. Chunked == unchunked also holds under
the sweep axis, so campaigns checkpoint/resume like single runs (the
stacked state is one pytree).

Results land in a tidy table keyed by sweep coordinates (one row per
trajectory per round) — ``campaign.csv`` always (appended per chunk, not
rewritten: O(S*R) total, not O(S*R^2)), ``campaign.parquet`` when
pandas+pyarrow are importable; ``benchmarks/figures.campaign_curves`` draws
multi-seed mean±band curves from it.
"""
from __future__ import annotations

import csv
import dataclasses
import pathlib
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sweeps
from repro.core.blockchain import param_digest
from repro.core.jobs import make_dataset, make_fault, validate_cohort
from repro.core.plan import program_signature
from repro.core.probes import PROBE_NAMES
from repro.core.rounds import init_state
from repro.data.pipeline import (DEDUP_STAGED_AXES, StackedSlabStager,
                                 make_slab_stager, stage_partitions_dedup)
from repro.launch.mesh import lane_mesh, shard_lanes
from repro.runtime.executor import Executor
from repro.telemetry import comms as comms_mod

_INT_COLS = ("seed", "traj", "round", "bucket", "lane", "async_buffer")


def _parse_cell(k: str, v: str):
    if k in _INT_COLS:
        return int(float(v))
    try:
        return float(v)
    except ValueError:
        return v                        # categorical coords stay strings


def read_results(csv_path) -> list:
    """Read a campaign.csv back into tidy rows (numbers where numeric,
    categorical coordinates as strings); blank cells (eval columns off the
    chunk tails) are dropped. The single parser for the campaign table —
    resume and figures both use it."""
    with open(csv_path, newline="") as f:
        return [{k: _parse_cell(k, v) for k, v in row.items() if v != ""}
                for row in csv.DictReader(f)]


def table_columns(rows, lead) -> list:
    """The tidy table's column order: lead columns, then the rest sorted."""
    return list(lead) + sorted({k for r in rows for k in r} - set(lead))


def write_parquet(rows, lead, out_dir):
    """Best-effort ``campaign.parquet`` next to the CSV (pandas+pyarrow
    optional; the CSV is the portable artifact). One helper for the
    single-campaign and merged-plan tables so their schemas cannot
    drift."""
    try:
        import pandas as pd
        pd.DataFrame(rows, columns=table_columns(rows, lead)).to_parquet(
            pathlib.Path(out_dir) / "campaign.parquet")
    except Exception:
        pass


class AppendTable:
    """Append-only tidy CSV writer.

    The PR 3 executor rewrote the whole table at every chunk boundary —
    O(S*R^2) rows written over a campaign. Here a chunk appends only its new
    rows; a full rewrite happens only when the column set changes (in
    practice: the first flush, and a resume re-adopting a prior table).
    ``appends``/``rewrites`` are the instrumentation the satellite test
    asserts on.
    """

    def __init__(self, path):
        self.path = pathlib.Path(path)
        self.appends = 0
        self.rewrites = 0
        self._fieldnames = None
        self._written = 0

    def reset(self):
        """Forget on-disk state (next flush rewrites) — the resume path."""
        self._fieldnames = None
        self._written = 0

    def flush(self, rows, lead):
        """Bring the CSV up to date with ``rows`` (lead columns first).
        The steady-state path only inspects the rows added since the last
        flush — per-boundary cost is O(new), not O(total) — and falls back
        to a full rewrite only when a new column appears."""
        new = rows[self._written:]
        self.path.parent.mkdir(parents=True, exist_ok=True)
        if (self._fieldnames is not None and self.path.exists()
                and self._written):
            grown = {k for r in new for k in r} - set(self._fieldnames)
            if not grown:
                if new:
                    with open(self.path, "a", newline="") as f:
                        csv.DictWriter(f,
                                       fieldnames=self._fieldnames
                                       ).writerows(new)
                    self.appends += 1
                self._written = len(rows)
                return self.path
        keys = table_columns(rows, lead)
        with open(self.path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=keys)
            w.writeheader()
            w.writerows(rows)
        self.rewrites += 1
        self._fieldnames = keys
        self._written = len(rows)
        return self.path


@dataclasses.dataclass
class CampaignExecutor(Executor):
    """Executor over the sweep axis: same compiled programs, outer vmap.

    ``job`` must carry a ``sweep:`` section (``job.sweep``) — or the planner
    passes an explicit ``lanes=(coords, fls)`` subset (one signature
    bucket). ``eval_fn`` keeps the single-run signature ``params -> dict``
    and is applied per trajectory lane. ``out_dir`` (if set) receives the
    results table at every chunk boundary.
    """
    out_dir: Optional[str] = None
    lanes: Optional[tuple] = None     # (coords, fls) bucket override
    parquet: bool = True              # planner buckets defer to the merge
    # Thread the per-lane alive mask through the compiled programs. The
    # planner sets this when a lane scheduler is attached; without one the
    # mask (and its per-round state select) stays out of the program
    # entirely, so scheduler-off campaigns pay nothing for schedulability.
    lane_scheduling: bool = False
    # Shard the sweep axis over this many devices (launch/mesh.lane_mesh);
    # a configs.base.MeshConfig is also accepted (its `lanes` axis).
    # 0 keeps the single-device vmap. S pads up to a multiple with dead
    # lanes, which threads the alive mask even scheduler-off (the pad is
    # maskwork, not recompilation).
    lane_devices: int = 0

    def __post_init__(self):
        if self.job.sweep is None:
            raise ValueError("CampaignExecutor needs a job with a sweep: "
                             "section (see core/sweeps.py for the axes)")
        self.spec = self.job.sweep
        if self.lanes is not None:
            self.coords = list(self.lanes[0])
            self.fls = list(self.lanes[1])
        else:
            self.coords = self.spec.coords()
            self.fls = sweeps.expand(self.job.fl, self.spec)
        sigs = {program_signature(f, self.job.arch) for f in self.fls}
        sigs.add(program_signature(self.job.fl, self.job.arch))
        if len(sigs) > 1:
            raise ValueError(
                "CampaignExecutor lanes span multiple program signatures "
                f"({len(sigs)}); heterogeneous sweeps (categorical axes "
                f"{self.spec.categorical_names}) must go through the "
                "planner: runtime.scheduler.PlanExecutor")
        self.S = len(self.fls)
        # a MeshConfig's `lanes` axis is an accepted spelling of the count;
        # its lanes=1 default means "no lane axis" (matching its shape/axes
        # properties), i.e. the single-device vmap, not a 1-device mesh
        if hasattr(self.lane_devices, "lanes"):
            self.lane_devices = (self.lane_devices.lanes
                                 if self.lane_devices.lanes > 1 else 0)
        self.lane_devices = int(self.lane_devices)
        self.mesh = lane_mesh(self.lane_devices) if self.lane_devices else None
        # pad S to a multiple of the device count with dead lanes (clones of
        # the last config: zero extra staged bytes through the dedup caches)
        d = max(self.lane_devices, 1)
        self.S_pad = -(-self.S // d) * d
        self._fls_pad = list(self.fls) + \
            [self.fls[-1]] * (self.S_pad - self.S)
        if self.job.fl.max_cohort > 0:
            # ragged client plane: cohort/population sizes are host-side
            # slab-plan values, so validate every lane's draw up front (a
            # lane sweeping cohort past n_clients must fail at build, not
            # silently clamp mid-campaign)
            for fl_s in self._fls_pad:
                validate_cohort(fl_s)
            if self.job.fl.mode == "async":
                raise NotImplementedError(
                    "ragged campaigns (max_cohort > 0) support sync mode "
                    "only: the async event schedule sizes by n_clients, "
                    "which the ragged plane makes a per-lane host value. "
                    "Run async ragged lanes as single Executors")
            if self.lane_devices:
                raise NotImplementedError(
                    "ragged campaigns (max_cohort > 0) do not shard over a "
                    "lane mesh yet: the stacked slab is restaged per chunk "
                    "on the host, which would break the zero-collective "
                    "lane-sharding contract. Use lane_devices=0")
        self.alive = np.ones(self.S_pad, np.float32)  # scheduler + pad mask
        self.alive[self.S:] = 0.0                     # pad lanes never run
        self._thread_alive = self.lane_scheduling or self.S_pad > self.S
        self._hyper_launch = None     # cached hyper+alive (device) dict
        self.results = []              # tidy rows: coords + traj/round/metrics
        self._tail_rows = []           # (lane, row) pairs, last round/lane
        self._table = (AppendTable(pathlib.Path(self.out_dir) /
                                   "campaign.csv")
                       if self.out_dir else None)
        super().__post_init__()

    # -- lane scheduler interface -----------------------------------------
    def drop_lane(self, s: int):
        """Zero-weight lane ``s`` from the next launch on: its state
        freezes inside the already-compiled program (the alive mask is a
        runtime input) and it stops producing table rows and ledger blocks.
        The planner keeps the lane -> drop-round record
        (``PlanExecutor.dropped``)."""
        if not self.lane_scheduling:
            raise RuntimeError(
                "drop_lane needs lane_scheduling=True at construction (the "
                "alive mask must be in the compiled program from launch 1 "
                "for a mid-campaign drop not to recompile it)")
        self.alive[s] = 0.0
        self._hyper_launch = None     # next launch re-stages the mask

    def alive_lanes(self):
        return [s for s in range(self.S) if self.alive[s] > 0]

    # -- scaffold hooks: deduped staging + vmapped init --------------------
    def _stage_data(self):
        """Data plane: restage per distinct (seed, partition, alpha);
        lanes sharing a triple share ONE staged root on device (the padded
        index matrices carry the lane->dataset indirection as offsets into
        the concatenated roots, so every lane's gather stays bitwise a
        single run's). Also builds the scalar plane + per-trajectory roots.
        ``self.data`` is the list of per-trajectory (x, y, parts) host
        views (eval_fn consumers index it by lane). Under a lane mesh the
        per-lane planes shard over ``lanes`` and the concatenated roots
        replicate (``stage_partitions_dedup(mesh=...)``)."""
        cfg = getattr(self.job.model, "cfg", None)
        if self.job.fl.max_cohort > 0:
            self._stage_ragged(cfg)
            return
        cache, trajs, keys = {}, [], []
        for fl_s in self._fls_pad:
            k = (fl_s.seed, fl_s.partition, fl_s.dirichlet_alpha)
            if k not in cache:
                ds = make_dataset(self.job.raw, fl_s, cfg)
                cache[k] = ds.distribute_into_chunks(
                    fl_s.partition, fl_s.n_clients, fl_s.dirichlet_alpha)
            trajs.append(cache[k])
            keys.append(k)
        self.trajectories = trajs
        self.data = trajs
        self.staged, self.lane_ds = stage_partitions_dedup(
            trajs, keys, mesh=self.mesh)
        self.roots = shard_lanes(sweeps.root_keys(self._fls_pad), self.mesh)
        self.hyper = shard_lanes(sweeps.scalar_plane(self._fls_pad),
                                 self.mesh)

    def _stage_ragged(self, cfg):
        """Ragged client plane: one ``SlabStager`` per lane (deduped on the
        full plan key — a stager's host cohort draw depends on the cohort
        sizes and the fault seed, not just the dataset triple), stacked by
        ``StackedSlabStager`` into per-chunk ``(S_pad, n, K, ...)`` slabs.
        ``self.staged`` stays ``None``: there is no resident root — each
        chunk's slab is assembled (and for streaming lanes, staged) on
        demand, exactly like the single-run ragged Executor."""
        cache, lanes = {}, []
        for fl_s in self._fls_pad:
            k = (fl_s.seed, fl_s.partition, fl_s.dirichlet_alpha,
                 fl_s.n_clients, fl_s.cohort, fl_s.max_cohort,
                 fl_s.straggler_overprovision, fl_s.streaming)
            if k not in cache:
                ds = make_dataset(self.job.raw, fl_s, cfg)
                cache[k] = make_slab_stager(ds, fl_s,
                                            make_fault(self.job.raw, fl_s))
            lanes.append(cache[k])
        self.stager = StackedSlabStager(lanes)
        self.trajectories = [getattr(ln, "data", None) for ln in lanes]
        self.data = self.trajectories
        self.staged = None
        self.lane_ds = None
        self.roots = shard_lanes(sweeps.root_keys(self._fls_pad), self.mesh)
        self.hyper = shard_lanes(sweeps.scalar_plane(self._fls_pad),
                                 self.mesh)

    def _init_state(self):
        fl = self.job.fl
        self.state = shard_lanes(jax.vmap(
            lambda key: init_state(self.job.model, self.job.strategy, fl,
                                   key, n_clients_local=fl.n_clients))(
            self.roots), self.mesh)

    def _maybe_restore(self):
        """Restore onto the live mesh — elastically: a checkpoint saves
        full logical arrays with the *saving* process's padded lane dim,
        and a different ``lane_devices`` at resume means a different
        ``S_pad``. The real lanes are always the leading ``S`` rows, and
        pad lanes are frozen at their initial state (``alive = 0`` from
        launch 1) which the fresh scaffold just rebuilt bitwise — so
        reconciliation is: keep the checkpoint's first S lanes, take the
        new pad tail from the scaffolded template, then re-place on the
        mesh. Saving on 4 devices and resuming on 1 (or vice versa) is
        therefore bitwise the uninterrupted run (tests/test_shard_sweep.py
        ::test_elastic_resume_across_device_counts)."""
        if not self.ckpt_dir:
            return
        from repro.checkpoint import ckpt as ckpt_mod
        last = ckpt_mod.latest_round(self.ckpt_dir)
        if last is None:
            return
        template = self.state
        restored, extra = ckpt_mod.restore(self.ckpt_dir, last, template)
        saved_s = extra.get("campaign_lanes")
        saved_grid = extra.get("campaign_grid")
        if (saved_s is not None and saved_s != self.S) or \
                (saved_grid is not None
                 and saved_grid != self._coords_digest()):
            raise ValueError(
                f"checkpoint was written by a different sweep grid "
                f"({saved_s} lanes, digest {saved_grid}) than this one "
                f"({self.S} lanes, digest {self._coords_digest()}); a "
                "resume needs the same grid (lane_devices may differ — "
                "only the padding is elastic). Point ckpt_dir elsewhere "
                "to start the new grid fresh")

        def fit(saved, tmpl):
            if saved.shape == tmpl.shape:
                return saved
            if saved.shape[1:] != tmpl.shape[1:] or saved.shape[0] < self.S:
                raise ValueError(
                    f"checkpoint leaf {saved.shape} does not fit campaign "
                    f"state {tmpl.shape} (S={self.S}); the checkpoint was "
                    "written by an incompatible campaign, not just a "
                    "different lane_devices")
            return jnp.concatenate([saved[:self.S], tmpl[self.S:]], 0)

        self.state = shard_lanes(jax.tree.map(fit, restored, template),
                                 self.mesh)
        self.round_idx = extra["next_round"]

    def _coords_digest(self) -> str:
        """Stable digest of the expanded sweep coordinates — the identity
        of the grid, not just its size (seeds [3,5] and [11,13] both have
        S=2 but share no lane)."""
        import hashlib
        canon = repr([sorted(c.items()) for c in self.coords])
        return hashlib.sha256(canon.encode()).hexdigest()[:16]

    def _ckpt_extra(self) -> dict:
        """The real (unpadded) lane count and the grid digest ride in the
        manifest: restore rejects a checkpoint from a different sweep grid
        instead of silently adopting lanes whose coordinates belong to
        another campaign (padding alone stays elastic)."""
        return dict(super()._ckpt_extra(), campaign_lanes=self.S,
                    campaign_grid=self._coords_digest())

    def _post_restore(self):
        """Resume path: re-adopt the pre-restart rows (completed chunks are
        flushed, so a crash loses at most the open chunk) — without this a
        resumed campaign would silently write a table missing every
        pre-resume round. The append table resets so the first post-resume
        flush rewrites the (possibly crash-truncated) file consistently."""
        if self.round_idx > 0 and self.out_dir:
            prior = pathlib.Path(self.out_dir) / "campaign.csv"
            if prior.exists():
                self.results = [r for r in read_results(prior)
                                if r["round"] < self.round_idx]
        if self._table is not None:
            self._table.reset()

    def _build_schedule(self, n_rounds: int):
        """Per-trajectory virtual-clock schedules, **deduplicated**: the
        schedule is a pure function of (seed, partition, alpha — they fix
        the fault stream and the weight vector — and staleness_exponent),
        so lanes sharing that key share ONE (E,) schedule on device (the
        ROADMAP schedule-plane item; async lanes swept only over scalar
        knobs used to duplicate their schedules S times the way data used
        to). ``sched_dev`` holds the U unique schedules stacked (U, E) —
        replicated under a lane mesh — and ``lane_sched`` (S,) maps each
        lane to its row; the event program gathers the row per lane, which
        relocates identical bytes, so every lane's event stream is bitwise
        its own single staging."""
        from repro.core.async_rounds import async_init_state
        from repro.runtime.clock import build_schedule

        fl = self.job.fl
        lens = np.asarray(self.staged["len"], np.float32)   # (S_pad, C)
        cache, uniq, lane_u = {}, [], []
        for s, fl_s in enumerate(self._fls_pad):
            k = (fl_s.seed, fl_s.partition, fl_s.dirichlet_alpha,
                 fl_s.staleness_exponent)
            if k not in cache:
                cache[k] = len(uniq)
                uniq.append(build_schedule(
                    make_fault(self.job.raw, fl_s), fl.n_clients,
                    n_rounds * self.events_per_round, lens[s],
                    buffer_size=fl.async_buffer,
                    staleness_exponent=fl_s.staleness_exponent,
                    max_staleness=fl.max_staleness,
                    concurrency=fl.async_concurrency))
            lane_u.append(cache[k])
        self.schedules = [uniq[u] for u in lane_u]   # per-lane host views
        self.schedule = self.schedules[0]       # horizon checks read len()
        self.lane_sched = np.asarray(lane_u, np.int32)
        from repro.core.probes import buffer_occupancy
        occ_uniq = [buffer_occupancy(sc.accept, sc.apply) for sc in uniq]
        self._occupancy_lane = np.stack([occ_uniq[u] for u in lane_u])
        devs = [sc.device_arrays() for sc in uniq]
        sched = {k: jnp.stack([d[k] for d in devs]) for k in devs[0]}
        self.sched_dev = shard_lanes(sched, self.mesh,
                                     {k: None for k in sched})
        self._lane_sched_dev = shard_lanes(jnp.asarray(self.lane_sched),
                                           self.mesh)
        if "hist" not in self.state:
            ring = self.schedules[0].ring
            self.state = shard_lanes(jax.vmap(
                lambda st: async_init_state(st, ring, fl,
                                            self.job.strategy))(self.state),
                self.mesh)

    # -- compiled programs: the Executor's, under an outer vmap ------------
    # The concatenated roots (x, y) are NOT mapped over the sweep axis
    # (DEDUP_STAGED_AXES): one device copy serves every lane. Neither are
    # the unique (U, E) schedules — each lane gathers its row by lane_sched
    # index. Under a lane mesh the mapped inputs arrive lanes-sharded, so
    # the same jitted vmap partitions into per-device lane shards with no
    # cross-device collectives.
    def _round_program(self, n_rounds: int):
        if n_rounds not in self._programs:
            # ragged lanes carry a per-lane slab (stacked leading S_pad dim
            # on every leaf); dedup lanes share the concatenated roots and
            # map only the idx/len planes
            staged_axes = 0 if self.ragged else DEDUP_STAGED_AXES

            def launch(s, staged, roots, hyper, start, n=n_rounds):
                return jax.vmap(
                    lambda st, sg, rt, hp:
                    self._multi(self.ctx, st, sg, rt, start, n, hp),
                    in_axes=(0, staged_axes, 0, 0))(
                    s, staged, roots, hyper)
            self._programs[n_rounds] = jax.jit(launch)
        return self._programs[n_rounds]

    def _event_program(self, n_events: int):
        key = ("async", n_events)
        if key not in self._programs:
            def launch(s, staged, sched, lane_u, roots, hyper, start,
                       n=n_events):
                return jax.vmap(
                    lambda st, sg, sd, u, rt, hp:
                    self._multi(self.ctx, st, sg,
                                jax.tree.map(lambda t: t[u], sd), rt,
                                start, n, hp),
                    in_axes=(0, DEDUP_STAGED_AXES, None, 0, 0, 0))(
                    s, staged, sched, lane_u, roots, hyper)
            self._programs[key] = jax.jit(launch)
        return self._programs[key]

    # -- chunk launches (the inherited _chunk_loop drives these) ----------
    def _launch_hyper(self):
        """The scalar plane, plus — under a lane scheduler, or whenever
        device padding added dead lanes — the alive mask as a runtime
        (S_pad,) input, so drops (and the padding itself) never recompile.
        Cached between launches; a drop invalidates it."""
        if not self._thread_alive:
            return self.hyper
        if self._hyper_launch is None:
            self._hyper_launch = dict(
                self.hyper,
                alive=shard_lanes(jnp.asarray(self.alive), self.mesh))
        return self._hyper_launch

    def _skip_dead_bucket(self, n: int):
        """All lanes dropped: the compiled program would freeze every lane
        anyway, so skip the launch and emit placeholder logger rows."""
        self._tail_rows = []
        return [{"n_alive": 0, "round_s": 0.0} for _ in range(n)]

    def _launch_sync(self, start: int, n: int):
        if not self.alive_lanes():
            return self._skip_dead_bucket(n)
        t0 = time.time()
        prog = self._round_program(n)
        if self.ragged:
            staged = self.stager.slab(start, n)
            self._record_slab_bytes(staged)
            self._prefetch_next(start, n)
        else:
            staged = self.staged
        args = (self.state, staged, self.roots, self._launch_hyper(),
                start)
        if self.recorder.enabled and self._cost_enabled:
            self._last_program = (n, prog, args)
        state, metrics = prog(*args)
        self.state = jax.block_until_ready(state)
        dt = time.time() - t0
        self._capture_probes(start, n, metrics.pop("probes", None))
        cols = self._account_comms(start, n)
        stacked = {k: np.asarray(v) for k, v in metrics.items()}  # (S, n)
        self._merge_comms_stacked(stacked, cols)
        return self._table_rows(stacked, start, n, dt)

    def _launch_async(self, start: int, n: int):
        if not self.alive_lanes():
            return self._skip_dead_bucket(n)
        epr = self.events_per_round
        n_ev = n * epr
        t0 = time.time()
        prog = self._event_program(n_ev)
        args = (self.state, self.staged, self.sched_dev,
                self._lane_sched_dev, self.roots, self._launch_hyper(),
                start * epr)
        if self.recorder.enabled and self._cost_enabled:
            self._last_program = (("async", n_ev), prog, args)
        state, metrics = prog(*args)
        self.state = jax.block_until_ready(state)
        dt = time.time() - t0
        probes = self._reduce_async_probes(metrics.pop("probes", None), n)
        ev = {k: np.asarray(v).reshape(self.S_pad, n, epr)
              for k, v in metrics.items()}
        if probes is not None:
            from repro.core.probes import staleness_hist
            self._capture_probes(
                start, n, probes,
                extra=self._async_probe_extras(start, n),
                hists={f"probe:staleness_hist:lane{s}": staleness_hist(
                    ev["staleness"][s], self.job.fl.max_staleness)
                    for s in self.alive_lanes()})
        cols = self._account_comms(start, n)
        stacked = {"loss": ev["loss"].mean(-1),
                   "staleness": ev["staleness"].mean(-1),
                   "applied": ev["applied"].sum(-1),
                   # per-lane virtual arrival time at each round window's
                   # last event (each lane reads its own schedule): async
                   # curves plot against virtual time even with comms off
                   "vtime": self._lane_vtime(start, n)}
        self._merge_comms_stacked(stacked, cols)
        return self._table_rows(stacked, start, n, dt)

    def _lane_vtime(self, start: int, n: int) -> np.ndarray:
        """(S_pad, n) virtual time at each round window's closing event."""
        epr = self.events_per_round
        idx = (start + np.arange(1, n + 1)) * epr - 1
        return np.stack([np.asarray(sc.vtime, np.float64)[idx]
                         for sc in self.schedules])

    def _async_probe_extras(self, start: int, n: int):
        """Per-lane buffer occupancy off each lane's own schedule."""
        epr = self.events_per_round
        occ = self._occupancy_lane[:, start * epr:(start + n) * epr]
        return {"buffer_occ": occ.reshape(self.S_pad, n, epr).mean(-1)}

    def _table_rows(self, stacked, start: int, n: int, dt: float):
        """Append per-(trajectory, round) rows to the tidy results table
        (alive lanes only — a dropped lane stops contributing past its drop
        round); return per-round rows (alive-lane means) for the inherited
        logger."""
        self._tail_rows = []
        live = self.alive_lanes()
        for s in live:
            for i in range(n):
                row = {**self.coords[s], "traj": s, "round": start + i,
                       **{k: float(v[s, i]) for k, v in stacked.items()},
                       "round_s": dt / n}
                self.results.append(row)
                if i == n - 1:
                    self._tail_rows.append((s, row))
        idx = np.asarray(live, np.int64)
        return [dict({k: float(v[idx, i].mean()) for k, v in stacked.items()},
                     round_s=dt / n, n_alive=len(live)) for i in range(n)]

    def _ledger_record(self, last: int):
        """One ledger block per (alive) trajectory lane: the digest of lane
        ``s`` equals the digest of the s-th single run (bitwise contract),
        so per-run provenance stays auditable — a digest of the stacked
        pytree would certify parameters no run produced."""
        for s in self.alive_lanes():
            params_s = jax.tree.map(lambda t: t[s], self.state["params"])
            self.job.ledger.record_global(last, params_s)
            self.kv.publish(f"global_digest/{last}/traj{s}",
                            param_digest(params_s))

    def _merge_eval(self, rows):
        """Per-lane eval at the chunk boundary: merged into each alive
        trajectory's tail row of the results table, means into the
        logger."""
        if self.eval_fn is None:
            return
        agg = {}
        for s, row in self._tail_rows:
            params_s = jax.tree.map(lambda t: t[s], self.state["params"])
            ev = {k: float(v) for k, v in self.eval_fn(params_s).items()}
            row.update(ev)
            for k, v in ev.items():
                agg.setdefault(k, []).append(v)
        rows[-1].update({k: float(np.mean(v)) for k, v in agg.items()})

    # -- probe plane: per-lane capture -------------------------------------
    def _capture_probes(self, start, n, probes, extra=None, hists=None):
        """Per-lane probe capture: matrices come back ``(S_pad, n)`` off the
        vmapped scan; rows land keyed like campaign.csv (coords + traj +
        round), alive lanes only — dead/padded lanes emit frozen (zero)
        probes inside the program and never reach the table."""
        if probes is None:
            return
        # one (S_pad, n, P) plane off the device, one tolist() per probe +
        # cached lane labels: the per-row work below is pure-python dict
        # building (see the base method's chunk=1 rationale)
        a = np.asarray(probes)
        cols = {name: a[..., j].tolist()
                for j, name in enumerate(PROBE_NAMES)}
        if extra:
            cols.update({k: np.asarray(v).tolist()
                         for k, v in extra.items()})
        items = sorted(cols.items())
        alive = self.alive_lanes()
        self._probe_lanes = [(s, f"lane{s}") for s in alive]
        for s in alive:
            coords = dict(self.coords[s], traj=s)
            for i in range(n):
                row = dict(coords, round=start + i)
                row.update((k, col[s][i]) for k, col in items)
                self.probe_rows.append(row)
        self._pending_probes = (start, n, cols, hists or {})

    def _probe_series(self, m, i: int) -> dict:
        """One counter series per alive lane -> per-lane Perfetto tracks."""
        return {label: m[s][i] for s, label in self._probe_lanes}

    def _probe_lead_columns(self):
        return [*self.spec.names, "traj", "round"]

    # -- comms plane: per-lane accountants ---------------------------------
    def _comms_setup(self):
        """One ``LaneComms`` accountant per (padded) lane, built from the
        lane's own expanded config + fault model — byte gating and the
        simulated clock see exactly the swept seeds/knobs the compiled
        program runs. All lanes in a bucket share the program signature, so
        one shape template (lane dim stripped; decentralized states also
        strip the per-client dim) serves every accountant."""
        if not self.comms_spec.enabled:
            return
        from repro.core.netmodel import shape_template
        tpl = shape_template(self.state["params"], strip_leading=True)
        if self.job.fl.topology == "decentralized":
            tpl = shape_template(tpl, strip_leading=True)
        self._comms = [comms_mod.LaneComms(
            fl=fl_s, csm=make_fault(self.job.raw, fl_s), template=tpl,
            pods=self.comms_spec.pods) for fl_s in self._fls_pad]

    def _account_comms(self, start: int, n: int):
        """Advance every lane's accountant: alive lanes account their
        rounds (async lanes off their own deduped schedule), dead/padded
        lanes emit frozen columns — mirroring ``freeze_unless`` so a
        dropped lane's cumulative bytes hold at the drop round. Rows land
        keyed like campaign.csv (coords + traj + round), alive lanes
        only."""
        if self._comms is None:
            return None
        per = []
        for s, lane in enumerate(self._comms):
            if self.alive[s] > 0:
                if self.mode == "async":
                    per.append(lane.async_rounds(start, n,
                                                 self.schedules[s],
                                                 self.events_per_round))
                else:
                    per.append(lane.sync_rounds(start, n))
            else:
                per.append(lane.frozen(n))
        cols = {k: np.stack([p[k] for p in per]) for k in per[0]}
        items = sorted(cols.items())
        alive = self.alive_lanes()
        self._comms_lanes = [(s, f"lane{s}") for s in alive]
        for s in alive:
            coords = dict(self.coords[s], traj=s)
            for i in range(n):
                row = dict(coords, round=start + i)
                row.update((k, float(col[s][i])) for k, col in items)
                self.comms_rows.append(row)
        self._pending_comms = (start, n, cols)
        return cols

    def _merge_comms_stacked(self, stacked: dict, cols):
        """Join the (S_pad, n) simulated-time / cumulative-byte planes into
        the stacked metrics — ``_table_rows`` then lands them per (lane,
        round) in the results table (the time-to-accuracy / bytes-to-
        accuracy x-axes) and as alive-lane means in the logger rows."""
        if cols:
            stacked.update({k: cols[k] for k in comms_mod.RESULT_COLUMNS})

    def _comms_series(self, m, i: int) -> dict:
        """One counter series per alive lane -> per-lane Perfetto tracks
        (``compression: [none, int8]`` sweeps render side by side)."""
        return {label: float(m[s][i]) for s, label in self._comms_lanes}

    def _comms_summaries(self) -> list:
        """Run-level ``comms_total`` payloads, one per real lane."""
        if self._comms is None:
            return []
        return [dict(self._comms[s].summary(), lane=s)
                for s in range(self.S)]

    def _comms_lead_columns(self):
        return [*self.spec.names, "traj", "round"]

    def _digest_record(self, event_mark: int, last: int):
        """Async digest cadence, per alive trajectory lane (same reasoning
        as ``_ledger_record``: digests must certify per-run params). Each
        block carries its lane's virtual arrival time at the event mark."""
        for s in self.alive_lanes():
            params_s = jax.tree.map(lambda t: t[s], self.state["params"])
            self._digest_blocks += 1
            self.job.ledger.append(
                last, "async_digest",
                {"event": int(event_mark), "traj": s,
                 "vtime": float(self.schedules[s].vtime[event_mark - 1]),
                 "digest": param_digest(params_s)})

    # -- flight-recorder hooks ---------------------------------------------
    def _telemetry_attrs(self) -> dict:
        """Launch-span attrs: lane occupancy at launch time (the padded
        width is what the compiled program actually scans)."""
        return {"n_alive": len(self.alive_lanes()), "S": self.S,
                "S_pad": self.S_pad}

    def _record_lane_telemetry(self):
        """Post-launch counter: alive/total lanes, plus per-shard alive
        counts under a lane mesh (lanes shard in contiguous blocks of
        ``S_pad // lane_devices`` — the shard with dead lanes is the one
        idling its device). Emitted only when occupancy changed (first
        launch, then per scheduler drop) — a steady campaign pays
        nothing per chunk for it."""
        values = {"alive": len(self.alive_lanes()), "total": self.S}
        if self.lane_devices:
            per = self.S_pad // self.lane_devices
            for d in range(self.lane_devices):
                values[f"shard{d}_alive"] = int(
                    (self.alive[d * per:(d + 1) * per] > 0).sum())
        if values != getattr(self, "_last_occupancy", None):
            self._last_occupancy = values
            self.recorder.counter("lane_occupancy",
                                  track=self.telemetry_track, **values)

    # -- results table -----------------------------------------------------
    def _lead_columns(self):
        return [*self.spec.names, "traj", "round"]

    def _finish_chunk(self, start: int, n: int, rows):
        super()._finish_chunk(start, n, rows)
        # append this chunk's rows: a crash loses at most the open chunk,
        # and resume re-adopts what is there
        if self._table is not None:
            with self.recorder.span("table_flush",
                                    track=self.telemetry_track):
                self._table.flush(self.results, self._lead_columns())

    def run(self, rounds: Optional[int] = None):
        state, logger = super().run(rounds)
        if self.out_dir:
            self._table.flush(self.results, self._lead_columns())
            if self.parquet:
                write_parquet(self.results, self._lead_columns(),
                              self.out_dir)
        return state, logger

    def trajectory_params(self, s: int):
        """Lane ``s``'s params (bitwise the s-th single run's; frozen at
        the drop round for scheduler-dropped lanes)."""
        return jax.tree.map(lambda t: np.asarray(t[s]),
                            self.state["params"])

    def write_results(self, out_dir=None):
        """Write the tidy results table in full: ``campaign.csv`` (always)
        and ``campaign.parquet`` (when pandas+pyarrow are importable).
        Schema: one row per (trajectory, round) — sweep coordinate columns
        in axis order, then ``traj``, ``round``, metric columns. The chunk
        loop appends incrementally instead (AppendTable); this is the
        explicit full-export entry point."""
        out = pathlib.Path(out_dir or self.out_dir or ".")
        out.mkdir(parents=True, exist_ok=True)
        table = (self._table if self._table is not None
                 and out == pathlib.Path(self.out_dir or ".")
                 else AppendTable(out / "campaign.csv"))
        table.reset()                  # force a consistent full rewrite
        csv_path = table.flush(self.results, self._lead_columns())
        write_parquet(self.results, self._lead_columns(), out)
        return csv_path
