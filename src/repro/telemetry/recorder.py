"""The flight recorder: nested spans + counters on the monotonic clock.

Event model (one JSON object per ``telemetry.jsonl`` line):

- ``{"kind": "meta", "schema": 1, "run": ..., "pid": ..., "unit": "us",
   "clock": "perf_counter_ns"}`` — first line of every file.
- ``{"kind": "span", "id": n, "parent": m|null, "depth": d, "name": ...,
   "track": ..., "t0_us": ..., "dur_us": ..., "attrs": {...}}`` — a closed
  span. IDs are assigned in *open* order and events are written in *close*
  order, so nesting reconstructs deterministically from (id, parent, depth)
  alone; wall times carry no ordering weight.
- ``{"kind": "counter", "name": ..., "track": ..., "t_us": ...,
   "values": {...}}`` — a point sample (staged bytes, lane occupancy,
  host RSS/CPU, quant-agg routing totals).

``track`` names the Perfetto track the event renders on: ``"run"`` for a
single executor, ``bucket<i>`` per planner bucket, ``"plan"`` for the
lockstep scheduler. Spans on one track nest by time containment (same tid),
which is exactly how Perfetto draws flame stacks.

A disabled recorder is a no-op: ``span()`` hands back a shared null context
and ``counter()`` returns immediately — the instrumented drivers pay a
dict-lookup per chunk boundary, nothing per round. Timing uses
``time.perf_counter_ns`` (monotonic); nothing here touches device code, so
telemetry cannot perturb compiled-program numerics.
"""
from __future__ import annotations

import json
import os
import pathlib
import time


class Span:
    """An open span; ``attrs`` may be updated until the ``with`` exits.

    Its own context manager (not a ``contextlib`` generator): the chunk
    loop opens several spans per chunk boundary, and the hand-rolled
    ``__enter__``/``__exit__`` pair keeps that on the right side of the
    recorder's <=5% overhead budget."""
    __slots__ = ("name", "track", "attrs", "id", "parent", "depth", "_t0",
                 "_rec")

    def __init__(self, rec, name, track, attrs, sid, parent, depth, t0):
        self.name, self.track, self.attrs = name, track, attrs
        self.id, self.parent, self.depth = sid, parent, depth
        self._t0 = t0
        self._rec = rec

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        rec = self._rec
        rec._stack.pop()
        rec._emit({"kind": "span", "id": self.id, "parent": self.parent,
                   "depth": self.depth, "name": self.name,
                   "track": self.track, "t0_us": self._t0,
                   "dur_us": rec._now_us() - self._t0,
                   "attrs": dict(self.attrs)})
        if not rec._stack:
            rec.flush()
        return False


class _NullSpan:
    """Stand-in yielded by a disabled recorder: accepts (and discards)
    ``attrs`` updates so instrumentation sites need no enabled-checks."""
    __slots__ = ()

    @property
    def attrs(self):
        return {}


class _NullCtx:
    __slots__ = ()

    def __enter__(self):
        return _NULL_SPAN

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()
_NULL_CTX = _NullCtx()


class FlightRecorder:
    """Host-side span/counter recorder streaming to ``telemetry.jsonl``.

    ``out_dir=None`` keeps events in memory only (``self.events``); with an
    out_dir the file is truncated on the recorder's first write (one file
    per recorder lifetime) and appended per event, flushed whenever the
    span stack empties. ``profile_chunks`` lists launch ordinals to wrap in
    a ``jax.profiler.trace`` capture (written under ``out_dir/jax_profile``).
    """

    def __init__(self, out_dir=None, run_name: str = "run",
                 enabled: bool = True, profile_chunks=()):
        self.enabled = enabled
        self.run_name = run_name
        self.out_dir = pathlib.Path(out_dir) if out_dir else None
        self.profile_chunks = frozenset(int(c) for c in profile_chunks)
        self.events: list = []
        self._stack: list = []
        self._pending: list = []       # emitted, not yet serialized
        self._next_id = 0
        self._t0_ns = time.perf_counter_ns()
        self._fh = None
        self._profile_warned = False

    @classmethod
    def from_job(cls, job, fallback_dir=None) -> "FlightRecorder":
        """Build from a job's ``telemetry:`` section (validated by
        ``core/jobs.load_job``). No section, or ``enabled: false`` -> a
        no-op recorder; an enabled section without ``out_dir`` falls back
        to the executor's run dir (events stay in memory if neither)."""
        t = (getattr(job, "raw", None) or {}).get("telemetry") or {}
        enabled = bool(t) and bool(t.get("enabled", True))
        return cls(
            out_dir=(t.get("out_dir") or fallback_dir) if enabled else None,
            run_name=getattr(job, "name", "run"), enabled=enabled,
            profile_chunks=t.get("profile_chunks") or ())

    # -- clock ------------------------------------------------------------
    def _now_us(self) -> int:
        return (time.perf_counter_ns() - self._t0_ns) // 1000

    # -- spans ------------------------------------------------------------
    def span(self, name: str, track: str = "run", **attrs):
        if not self.enabled:
            return _NULL_CTX
        stack = self._stack
        sp = Span(self, name, track, attrs, self._next_id,
                  stack[-1].id if stack else None, len(stack),
                  self._now_us())
        self._next_id += 1
        stack.append(sp)
        return sp

    def counter(self, name: str, track: str = "run", *, t_us=None, **values):
        """Point sample; ``t_us`` backdates it onto the recorder clock (the
        probe drain stamps per-round samples interpolated across the launch
        span they were computed inside — they are device values, and the
        host only sees them at the chunk boundary)."""
        if not self.enabled:
            return
        self._emit({"kind": "counter", "name": name, "track": track,
                    "t_us": self._now_us() if t_us is None else int(t_us),
                    "values": values})

    def profile(self, ordinal: int):
        """``jax.profiler`` capture context for launch ``ordinal`` when the
        ``profile_chunks`` knob lists it (else a no-op context). Capture
        failures degrade to a one-time warning — profiling is a debugging
        aid, never a run dependency."""
        if not self.enabled or ordinal not in self.profile_chunks:
            return _NULL_CTX
        try:
            import jax
            d = (self.out_dir or pathlib.Path(".")) / "jax_profile"
            d.mkdir(parents=True, exist_ok=True)
            return jax.profiler.trace(str(d))
        except Exception as e:                        # pragma: no cover
            if not self._profile_warned:
                import warnings
                warnings.warn(f"jax.profiler capture unavailable ({e!r}); "
                              "profile_chunks ignored", stacklevel=2)
                self._profile_warned = True
            return _NULL_CTX

    # -- persistence ------------------------------------------------------
    def _emit(self, event: dict):
        """Record an event; serialization is deferred to ``flush()`` (the
        steady-state cost of an event is two list appends)."""
        self.events.append(event)
        if self.out_dir is not None:
            self._pending.append(event)

    def flush(self):
        """Serialize + write everything emitted since the last flush (one
        write call), and push it to the OS. Fired whenever the span stack
        empties — i.e. per chunk boundary — so a crash loses at most the
        open chunk's events."""
        if not self._pending:
            return
        if self._fh is None:
            self.out_dir.mkdir(parents=True, exist_ok=True)
            self._fh = open(self.out_dir / "telemetry.jsonl", "w")
            self._fh.write(json.dumps(
                {"kind": "meta", "schema": 1, "run": self.run_name,
                 "pid": os.getpid(), "unit": "us",
                 "clock": "perf_counter_ns"}) + "\n")
        self._fh.write("".join(
            json.dumps(e, separators=(",", ":")) + "\n"
            for e in self._pending))
        self._pending.clear()
        self._fh.flush()

    def close(self):
        self.flush()
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __del__(self):                                # pragma: no cover
        try:
            self.close()
        except Exception:
            pass


def read_events(path) -> list:
    """Parse a ``telemetry.jsonl`` (or a run dir containing one) back into
    event dicts — the single parser the exporter, report, and tests use."""
    p = pathlib.Path(path)
    if p.is_dir():
        p = p / "telemetry.jsonl"
    if not p.exists():
        raise FileNotFoundError(
            f"no telemetry.jsonl at {p} — was the run's job missing a "
            "telemetry: {enabled: true, out_dir: ...} section?")
    lines = p.read_text().splitlines()
    if not any(line.strip() for line in lines):
        raise ValueError(
            f"empty telemetry.jsonl at {p} — the run wrote no events "
            "(crashed before the first flush, or telemetry disabled?)")
    events = []
    for i, line in enumerate(lines):
        if not line.strip():
            continue
        try:
            events.append(json.loads(line))
        except json.JSONDecodeError:
            if i == len(lines) - 1:
                # a crash mid-write leaves one torn trailing line; everything
                # before it is intact (events are appended whole-line)
                break
            raise ValueError(
                f"corrupt telemetry.jsonl at {p}: line {i + 1} is not "
                "valid JSON (truncated mid-run?)") from None
    if not events:
        raise ValueError(
            f"empty telemetry.jsonl at {p} — only a torn partial line "
            "(crashed during the first flush?)")
    return events
