"""Comms observatory — the job-facing face of the wire-traffic plane.

A ``comms:`` job section turns on **pure host-side** traffic accounting
(``core/netmodel.py``): per-round uplink/downlink byte totals gated by the
real cohort masks / async accept flags, and a simulated wall-clock that
composes LinkModel transfer times with the virtual clock's compute
durations. Like the flight recorder (PR 7) and the probe plane (PR 8),
nothing device-side changes — comms-on trajectories are bitwise comms-off.

Outputs, riding the PR 7/8 plumbing:

- ``comms.csv`` — tidy per-round rows keyed like ``campaign.csv``
  (sweep coords + traj + round), columns ``core.netmodel.COMMS_COLUMNS``;
- ``comms:*`` Perfetto counter tracks (cumulative per-direction bytes +
  the virtual-time track, one series per alive campaign lane) back-dated
  across the launch span, plus a run-level ``comms_total`` counter the
  ``trace report`` comms section renders;
- ``sim_time_s`` / ``cum_bytes`` columns joined onto the campaign results
  rows, so eval metrics plot directly as time-to-accuracy and
  bytes-to-accuracy curves (``benchmarks/figures.py``).

Job section::

    comms:
      enabled: true          # presence of the section already enables
      out_dir: runs/exp1     # comms.csv target (falls back like probes)
      pods: 4                # hierarchical backbone pods (byte model only)

LinkModel knobs (per-client bandwidth tiers + latency) live in the
``runtime:`` section — they are ``ClientSystemModel`` fields
(``up_mbps`` / ``down_mbps`` / ``link_tiers`` / ``link_tier_factor`` /
``latency_s``), drawn from a dedicated Philox tag so schedules stay
prefix-stable with the link model on or off.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

# re-exported so executor/test code has one import surface for the plane
from repro.core.netmodel import COMMS_COLUMNS, LaneComms  # noqa: F401

# the cumulative columns streamed as Perfetto counter tracks per launch
COUNTER_COLUMNS = ("cum_up_bytes", "cum_down_bytes", "sim_time_s")
# the columns joined onto the campaign/eval result rows (the
# time-to-accuracy / bytes-to-accuracy x-axes)
RESULT_COLUMNS = ("sim_time_s", "cum_bytes")


@dataclasses.dataclass(frozen=True)
class CommsSpec:
    """Parsed ``comms:`` job section (validated by ``core/jobs.load_job``).

    ``enabled`` turns the accounting plane on; ``out_dir`` receives
    ``comms.csv`` (falls back to the telemetry out_dir, then the executor's
    out_dir — rows stay in memory when none is set); ``pods`` is the
    hierarchical backbone width the byte model bills cross-pod hops for."""
    enabled: bool = False
    out_dir: Optional[str] = None
    pods: int = 1

    def __post_init__(self):
        if int(self.pods) < 1:
            raise ValueError(f"comms.pods must be >= 1, got {self.pods}")

    @classmethod
    def from_job(cls, job) -> "CommsSpec":
        """Build from a job's ``comms:`` section (absent -> disabled)."""
        c = (getattr(job, "raw", None) or {}).get("comms") or {}
        return cls(enabled=bool(c) and bool(c.get("enabled", True)),
                   out_dir=c.get("out_dir"),
                   pods=int(c.get("pods", 1)))
