"""Chrome-trace/Perfetto export + terminal time-breakdown report.

Usage (run dir = wherever the job's ``telemetry: out_dir`` streamed
``telemetry.jsonl``):

    python -m repro.telemetry.trace <run_dir>            # -> trace.json
    python -m repro.telemetry.trace report <run_dir>     # terminal table

``trace.json`` is Chrome trace-event JSON (the object form Perfetto's
legacy importer loads directly at https://ui.perfetto.dev): one *process*
per recorder track (``run``, ``bucket<i>``, ``plan``) so every planner
bucket / lane shard gets its own named track, complete ("X") events for
spans — same-tid time containment renders the nesting as a flame stack —
and counter ("C") tracks for staged bytes, lane occupancy (with per-shard
series under a lane mesh), host RSS/CPU, and quant-agg routing.

``report`` collates span *self time* (duration minus enclosed children, so
nothing double-counts) into the compile/execute/stage/io breakdown the
paper's dashboard shows, plus a per-track program table. "compile" is the
launches whose jit-cache count grew during the call (their duration
includes the first execution — attribution, not a profiler).
"""
from __future__ import annotations

import json
import pathlib
import sys

from repro.telemetry.recorder import read_events

# span name -> report category; "launch" splits compile/execute on the
# per-span compile_delta attr, anything unlisted lands in "other"
_CATEGORY = {
    "stage_data": "stage", "build_schedule": "stage",
    "init_state": "init",
    "restore": "io", "checkpoint_save": "io", "ledger": "io", "eval": "io",
    "table_flush": "io", "parquet": "io", "scheduler": "io",
    "finish_chunk": "io", "probe_flush": "io", "comms_flush": "io",
    "digest": "io",
    "scaffold": "host", "chunk": "host",
}
_CATEGORY_ORDER = ("compile", "execute", "stage", "io", "init", "host",
                   "other")


def _span_category(ev: dict) -> str:
    if ev["name"] == "launch":
        return "compile" if ev["attrs"].get("compile_delta", 0) > 0 \
            else "execute"
    return _CATEGORY.get(ev["name"], "other")


def _self_times(spans) -> dict:
    """Span id -> duration minus the sum of its direct children (us)."""
    self_us = {ev["id"]: ev["dur_us"] for ev in spans}
    for ev in spans:
        if ev["parent"] is not None and ev["parent"] in self_us:
            self_us[ev["parent"]] -= ev["dur_us"]
    return self_us


def to_chrome_trace(events) -> dict:
    """Event dicts -> Chrome trace-event JSON (object form)."""
    tracks: list = []
    for ev in events:
        t = ev.get("track")
        if t is not None and t not in tracks:
            tracks.append(t)
    pid_of = {t: i + 1 for i, t in enumerate(tracks)}
    out = []
    for t, pid in pid_of.items():
        out.append({"ph": "M", "name": "process_name", "pid": pid,
                    "args": {"name": t}})
        out.append({"ph": "M", "name": "thread_name", "pid": pid, "tid": 1,
                    "args": {"name": "host"}})
    for ev in events:
        if ev["kind"] == "span":
            out.append({"ph": "X", "name": ev["name"], "cat": "span",
                        "pid": pid_of[ev["track"]], "tid": 1,
                        "ts": ev["t0_us"], "dur": ev["dur_us"],
                        "args": dict(ev["attrs"], span_id=ev["id"])})
        elif ev["kind"] == "counter":
            vals = {k: v for k, v in ev["values"].items()
                    if isinstance(v, (int, float))}
            if vals:
                out.append({"ph": "C", "name": ev["name"],
                            "pid": pid_of[ev["track"]], "tid": 1,
                            "ts": ev["t_us"], "args": vals})
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def export(run_dir, out_path=None) -> pathlib.Path:
    """``telemetry.jsonl`` under ``run_dir`` -> ``run_dir/trace.json``."""
    run_dir = pathlib.Path(run_dir)
    events = read_events(run_dir)
    out_path = pathlib.Path(out_path) if out_path \
        else (run_dir if run_dir.is_dir() else run_dir.parent) / "trace.json"
    with open(out_path, "w") as f:
        json.dump(to_chrome_trace(events), f)
    return out_path


def report(run_dir_or_events) -> str:
    """The terminal time-breakdown table (paper dashboard rendering):
    per-category self-time totals + shares, then per-track programs."""
    events = (run_dir_or_events
              if isinstance(run_dir_or_events, list)
              else read_events(run_dir_or_events))
    spans = [e for e in events if e.get("kind") == "span"]
    if not spans:
        return "(no spans recorded)"
    meta = next((e for e in events if e.get("kind") == "meta"), {})
    self_us = _self_times(spans)
    cat_us: dict = {}
    cat_n: dict = {}
    for ev in spans:
        c = _span_category(ev)
        cat_us[c] = cat_us.get(c, 0) + max(self_us[ev["id"]], 0)
        cat_n[c] = cat_n.get(c, 0) + 1
    wall_us = max(e["t0_us"] + e["dur_us"] for e in spans) \
        - min(e["t0_us"] for e in spans)
    wall_us = max(wall_us, 1)
    lines = [f"== telemetry report: {meta.get('run', '?')} "
             f"(wall {wall_us / 1e6:.2f}s, {len(spans)} spans) ==",
             f"  {'category':>10} {'time_s':>9} {'share':>7} {'spans':>6}"]
    known = [c for c in _CATEGORY_ORDER if c in cat_us]
    known += sorted(set(cat_us) - set(known))
    for c in known:
        lines.append(f"  {c:>10} {cat_us[c] / 1e6:9.3f} "
                     f"{100 * cat_us[c] / wall_us:6.1f}% {cat_n[c]:6d}")

    # per-track program table (the per-bucket attribution the planner's
    # "B compiled programs, not S" claim reads)
    tracks: list = []
    for ev in spans:
        if ev["track"] not in tracks:
            tracks.append(ev["track"])
    occupancy: dict = {}
    cost: dict = {}
    comms: list = []
    for e in events:
        if e.get("kind") != "counter":
            continue
        if e["name"] == "lane_occupancy":
            occupancy[e["track"]] = e["values"]
        elif e["name"] == "program_cost":
            # per-program FLOPs/bytes (Lowered.cost_analysis, recorded once
            # per compiled program on its compile launch) — summed per track
            c = cost.setdefault(e["track"], {"flops": 0.0, "bytes": 0.0})
            c["flops"] += float(e["values"].get("flops", 0.0))
            c["bytes"] += float(e["values"].get("bytes_accessed", 0.0))
        elif e["name"] == "comms_total":
            comms.append((e["track"], e["values"]))
    lines.append(f"  {'track':>10} {'launches':>9} {'compiles':>9} "
                 f"{'execute_s':>10} {'compile_s':>10} {'lanes':>8} "
                 f"{'gflops':>8} {'GB':>7}")
    for t in tracks:
        launches = [e for e in spans
                    if e["track"] == t and e["name"] == "launch"]
        if not launches:
            continue
        cold = [e for e in launches
                if e["attrs"].get("compile_delta", 0) > 0]
        warm_us = sum(e["dur_us"] for e in launches) \
            - sum(e["dur_us"] for e in cold)
        occ = occupancy.get(t)
        lanes = (f"{occ['alive']}/{occ['total']}" if occ else "-")
        c = cost.get(t)
        gflops = f"{c['flops'] / 1e9:8.2f}" if c else f"{'-':>8}"
        gb = f"{c['bytes'] / 1e9:7.2f}" if c else f"{'-':>7}"
        lines.append(
            f"  {t:>10} {len(launches):9d} "
            f"{sum(e['attrs'].get('compile_delta', 0) for e in launches):9d}"
            f" {warm_us / 1e6:10.3f}"
            f" {sum(e['dur_us'] for e in cold) / 1e6:10.3f} {lanes:>8} "
            f"{gflops} {gb}")

    # comms observatory section (telemetry/comms.py): one row per
    # ``comms_total`` payload — per lane under a campaign — with the
    # simulated wall-clock and the achieved uplink compression ratio
    # (uplink bytes / dense-equivalent uplink bytes)
    if comms:
        lines.append(f"  {'comms':>10} {'lane':>6} {'up_MB':>9} "
                     f"{'down_MB':>9} {'overlay_MB':>10} {'ratio':>7} "
                     f"{'sim_s':>9}")
        for track, v in comms:
            dense = float(v.get("dense_up_bytes", 0.0))
            ratio = (f"{float(v.get('up_bytes', 0.0)) / dense:7.3f}"
                     if dense else f"{'-':>7}")
            lane = v.get("lane")
            lines.append(
                f"  {track:>10} {('-' if lane is None else lane):>6} "
                f"{float(v.get('up_bytes', 0.0)) / 1e6:9.2f} "
                f"{float(v.get('down_bytes', 0.0)) / 1e6:9.2f} "
                f"{float(v.get('overlay_bytes', 0.0)) / 1e6:10.2f} "
                f"{ratio} "
                f"{float(v.get('sim_time_s', 0.0)):9.3f}")
    return "\n".join(lines)


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    usage = ("usage: python -m repro.telemetry.trace <run_dir>  "
             "| report <run_dir>  | export <run_dir> [out.json]")
    if not argv:
        print(usage, file=sys.stderr)
        return 2
    # a missing/empty/truncated telemetry.jsonl (crash mid-chunk, wrong
    # dir) is a user-facing condition, not a traceback: read_events raises
    # FileNotFoundError/ValueError naming the path — print and exit 1
    try:
        if argv[0] == "report":
            if len(argv) != 2:
                print(usage, file=sys.stderr)
                return 2
            print(report(argv[1]))
            return 0
        if argv[0] == "export":
            argv = argv[1:]
        if not 1 <= len(argv) <= 2:
            print(usage, file=sys.stderr)
            return 2
        out = export(argv[0], *argv[1:])
    except (FileNotFoundError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 1
    except BrokenPipeError:
        # `... report run/ | head` closes stdout early — not an error
        return 0
    print(f"wrote {out} (load at https://ui.perfetto.dev)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
