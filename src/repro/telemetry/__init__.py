"""Flight recorder — host-side observability for the FL drivers.

The paper pitches the Performance Logger + FL-Dashboard as a first-class
component; this package is that component grown into an *attribution* layer:
nested monotonic-clock spans over the chunk-boundary seams of the sync,
async, and campaign drivers (compile vs execute vs staging vs boundary I/O),
per-launch counters (compile deltas, quant-agg routing, staged bytes, lane
occupancy, host RSS/CPU), a ``telemetry.jsonl`` event stream per run dir,
and a Chrome-trace/Perfetto exporter + terminal report
(``python -m repro.telemetry.trace <run_dir>``).

Everything here is host-side Python on the monotonic clock — zero
device-side code — so the drivers' bitwise contracts hold with telemetry on
or off (tests/test_telemetry.py asserts it for all three drivers).
"""
from repro.telemetry.recorder import FlightRecorder, read_events

__all__ = ["FlightRecorder", "read_events"]
