"""Model assembly for every assigned architecture family.

One code path serves three phases:
- ``train``   — loss + grads; activations sequence-sharded over ``model``
                (SP), batch over ``pod``/``data``; weights ZeRO-3: stored
                model-sharded, all-gathered per layer inside the layer scan.
- ``prefill`` — forward-only train path emitting sequence-sharded KV caches.
- ``decode``  — one token; TP-resident weights, chunk-parallel cache attention.

Everything is written against an AxisCtx, so with AxisCtx() the same code is
an ordinary single-device model (the oracle for tests).

Embeddings / LM heads are vocab-sharded over ``model``: lookup is a masked
local take + psum, logits stay local-V, and the softmax-xent is computed
distributed (pmax/psum over the vocab shards) — the full (B,S,V) logits tensor
never exists on one chip.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (dense_init, embed_init, layer_norm, rms_norm)
from repro.sharding.axes import AxisCtx


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_param_shapes(cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    D, F = cfg.d_model, d_ff or cfg.d_ff
    if cfg.family == "encdec":   # whisper: 2-matrix GELU MLP with biases
        return {"w1": (D, F), "b1": (F,), "w2": (F, D), "b2": (D,)}
    return {"w1": (D, F), "w3": (D, F), "w2": (F, D)}


def mlp_forward(ctx: AxisCtx, w: dict, x, cfg: ModelConfig, *, tp: bool = False):
    if "w3" in w:
        g = attn.col_matmul(ctx, x, w["w1"], None, tp)
        u = attn.col_matmul(ctx, x, w["w3"], None, tp)
        return attn.row_matmul(ctx, jax.nn.silu(g) * u, w["w2"], tp)
    h = jax.nn.gelu(attn.col_matmul(ctx, x, w["w1"], w["b1"], tp))
    return attn.row_matmul(ctx, h, w["w2"], tp) + w["b2"]


# ---------------------------------------------------------------------------
# Embedding / logits / loss (vocab-sharded over `model`)
# ---------------------------------------------------------------------------

def embed_lookup(ctx: AxisCtx, embed_loc, tokens, *, tied: bool = False,
                 tokens_replicated: bool = False, out_dtype=None):
    """Input-side embedding under sharding.

    Untied: ``embed_loc`` is (V, D_loc) D-sharded — every chip looks up its
    OWN (possibly sequence-sharded) token rows locally, then the feature dim
    is all-gathered (S_loc x D bytes — tiny). Correct for arbitrary token
    sharding, unlike a vocab-shard mask+psum (which would sum different
    positions across shards).

    Tied (vocab-sharded (V_loc, D), shared with the LM head): when tokens
    are replicated over the vocab axis (decode) a masked lookup + psum is
    exact; otherwise the caller must pass the pre-gathered full matrix.
    """
    if tied:
        V = embed_loc.shape[0]
        if tokens_replicated and ctx.vaxis is not None:
            off = ctx.index(ctx.vaxis) * V
            ids = tokens - off
            ok = (ids >= 0) & (ids < V)
            x = embed_loc[jnp.clip(ids, 0, V - 1)] \
                * ok[..., None].astype(embed_loc.dtype)
            x = ctx.psum(x.astype(jnp.float32), ctx.vaxis)
        else:
            x = embed_loc[tokens]       # full matrix (gathered by caller)
        return x.astype(out_dtype or embed_loc.dtype)
    x = embed_loc[tokens]               # (B, S_loc, D_loc)
    x = ctx.all_gather(x, ctx.vaxis, axis=x.ndim - 1)
    return x.astype(out_dtype or embed_loc.dtype)


def softmax_xent_vshard(ctx: AxisCtx, logits_loc, labels, valid=None):
    """Distributed stable cross-entropy. logits_loc: (B, S, V_loc) f32;
    labels: (B, S) global ids. Returns mean loss over valid tokens."""
    V_loc = logits_loc.shape[-1]
    off = ctx.index(ctx.vaxis) * V_loc
    m = jax.lax.stop_gradient(logits_loc.max(-1))
    if ctx.vaxis is not None:
        m = jax.lax.pmax(m, ctx.vaxis)
    m = jax.lax.stop_gradient(m)  # stabilizer only; lse grads are m-invariant
    se = jnp.exp(logits_loc - m[..., None]).sum(-1)
    se = ctx.psum(se, ctx.vaxis)
    lse = m + jnp.log(se)
    ids = labels - off
    ok = (ids >= 0) & (ids < V_loc)
    tgt = jnp.take_along_axis(
        logits_loc, jnp.clip(ids, 0, V_loc - 1)[..., None], -1)[..., 0]
    tgt = ctx.psum(tgt * ok, ctx.vaxis)
    nll = lse - tgt
    if valid is None:
        valid = jnp.ones_like(nll)
    loss = (nll * valid).sum() / jnp.maximum(valid.sum(), 1.0)
    return ctx.pmean(loss, tuple(a for a in (ctx.pod, ctx.data) if a))


# ---------------------------------------------------------------------------
# Block parameter construction
# ---------------------------------------------------------------------------

def _norm_shapes(cfg):
    if cfg.family == "encdec":
        return {"w": (cfg.d_model,), "b": (cfg.d_model,)}
    return {"w": (cfg.d_model,)}


def _apply_norm(w, x, cfg):
    if "b" in w:
        return layer_norm(x, w["w"], w["b"], eps=1e-5)
    return rms_norm(x, w["w"], cfg.norm_eps)


def dense_block_shapes(cfg: ModelConfig) -> dict:
    s = {"ln1": _norm_shapes(cfg), "ln2": _norm_shapes(cfg),
         "attn": attn.attn_param_shapes(cfg)}
    if cfg.moe is not None and cfg.family == "moe":
        s["moe"] = moe_mod.moe_param_shapes(cfg)
        if cfg.moe.dense_residual_d_ff:
            s["dense_mlp"] = mlp_param_shapes(cfg, cfg.moe.dense_residual_d_ff)
            s["ln3"] = _norm_shapes(cfg)
    else:
        s["mlp"] = mlp_param_shapes(cfg)
    return s


def hybrid_period_shapes(cfg: ModelConfig) -> dict:
    """Jamba period: 1 attn + 7 mamba mixers; 4 MoE + 4 MLP FFNs; 16 norms."""
    n_mamba = cfg.hybrid.period - 1
    n_moe = cfg.hybrid.period // cfg.moe.moe_every
    n_mlp = cfg.hybrid.period - n_moe
    return {
        "attn": attn.attn_param_shapes(cfg),
        "mamba": jax.tree.map(lambda sh: (n_mamba,) + sh,
                              ssm_mod.mamba_param_shapes(cfg),
                              is_leaf=lambda x: isinstance(x, tuple)),
        "moe": jax.tree.map(lambda sh: (n_moe,) + sh,
                            moe_mod.moe_param_shapes(cfg),
                            is_leaf=lambda x: isinstance(x, tuple)),
        "mlp": jax.tree.map(lambda sh: (n_mlp,) + sh,
                            mlp_param_shapes(cfg),
                            is_leaf=lambda x: isinstance(x, tuple)),
        "ln_mix": {"w": (cfg.hybrid.period, cfg.d_model)},
        "ln_ffn": {"w": (cfg.hybrid.period, cfg.d_model)},
    }


def xlstm_period_shapes(cfg: ModelConfig) -> dict:
    n_m = cfg.ssm.slstm_every - 1
    return {
        "mlstm": jax.tree.map(lambda sh: (n_m,) + sh,
                              ssm_mod.mlstm_param_shapes(cfg),
                              is_leaf=lambda x: isinstance(x, tuple)),
        "slstm": ssm_mod.slstm_param_shapes(cfg),
        "ln": {"w": (cfg.ssm.slstm_every, cfg.d_model)},
    }


def encdec_block_shapes(cfg: ModelConfig, cross: bool) -> dict:
    s = {"ln1": _norm_shapes(cfg), "attn": attn.attn_param_shapes(cfg),
         "ln2": _norm_shapes(cfg), "mlp": mlp_param_shapes(cfg)}
    if cross:
        s["ln_x"] = _norm_shapes(cfg)
        s["xattn"] = attn.attn_param_shapes(cfg)
    return s


def block_shapes(cfg: ModelConfig) -> dict:
    if cfg.family == "hybrid":
        return hybrid_period_shapes(cfg)
    if cfg.family == "ssm":
        return xlstm_period_shapes(cfg)
    return dense_block_shapes(cfg)


def n_stacks(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.hybrid.period
    if cfg.family == "ssm":
        return cfg.n_layers // cfg.ssm.slstm_every
    return cfg.n_layers


def param_shapes(cfg: ModelConfig) -> dict:
    """Full (unsharded) logical shapes for the whole model, as a pytree of
    tuples. Stacked blocks carry the leading stack dim."""
    Vp, D = cfg.padded_vocab, cfg.d_model
    L = n_stacks(cfg)
    stack = lambda tree: jax.tree.map(lambda sh: (L,) + sh, tree,
                                      is_leaf=lambda x: isinstance(x, tuple))
    p = {"embed": (Vp, D), "final_norm": _norm_shapes(cfg),
         "blocks": stack(block_shapes(cfg))}
    if not cfg.tie_embeddings:
        p["lm_head"] = (D, Vp)
    if cfg.family == "encdec":
        Le = cfg.n_enc_layers
        p["enc_blocks"] = jax.tree.map(
            lambda sh: (Le,) + sh, encdec_block_shapes(cfg, cross=False),
            is_leaf=lambda x: isinstance(x, tuple))
        p["blocks"] = jax.tree.map(
            lambda sh: (cfg.n_layers,) + sh, encdec_block_shapes(cfg, cross=True),
            is_leaf=lambda x: isinstance(x, tuple))
        p["enc_final_norm"] = _norm_shapes(cfg)
    return p


def init_params(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    """Materialize real parameters (reduced/small configs; tests, examples)."""
    shapes = param_shapes(cfg)
    leaves, treedef = jax.tree.flatten(shapes, is_leaf=lambda x: isinstance(x, tuple))
    paths = jax.tree_util.tree_flatten_with_path(
        shapes, is_leaf=lambda x: isinstance(x, tuple))[0]
    ks = jax.random.split(key, len(leaves))
    out = []
    for (path, shape), k in zip(paths, ks):
        name = jax.tree_util.keystr(path)
        if "norm" in name or "ln" in name or "o_norm" in name:
            out.append(jnp.ones(shape, dtype) if not name.endswith("['b']")
                       else jnp.zeros(shape, dtype))
        elif name.endswith("['b']") or "bias" in name or \
                name.endswith("['b1']") or name.endswith("['b2']") or \
                name.endswith("['bq']") or name.endswith("['bk']") or \
                name.endswith("['bv']") or name.endswith("['conv_b']") or \
                name.endswith("['dt_bias']"):
            out.append(jnp.zeros(shape, dtype))
        elif name.endswith("['A_log']"):
            N = shape[-1]
            out.append(jnp.log(jnp.broadcast_to(
                jnp.arange(1, N + 1, dtype=jnp.float32), shape)).astype(dtype))
        elif name.endswith("['D_skip']"):
            out.append(jnp.ones(shape, dtype))
        elif name.endswith("['embed']"):
            out.append(embed_init(k, shape, dtype))
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[0]
            out.append(dense_init(k, shape, in_dim=fan_in, dtype=dtype))
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _attn_layer(ctx, cfg, w, x, *, phase, cache=None, length=None, tp=False):
    if phase in ("train", "prefill"):
        fn = attn.mla_seqsharded if cfg.attn_type == "mla" else attn.gqa_seqsharded
        if phase == "prefill":
            o, new_cache = fn(ctx, w, x, cfg, return_cache=True)
            return o, new_cache
        return fn(ctx, w, x, cfg), None
    fn = attn.mla_decode if cfg.attn_type == "mla" else attn.gqa_decode
    return fn(ctx, w, x, cache, length, cfg, tp=tp)


def _dense_block(ctx, cfg, w, x, *, phase, cache=None, length=None, tp=False):
    """Returns (x, new_cache, aux)."""
    h = _apply_norm(w["ln1"], x, cfg)
    o, new_cache = _attn_layer(ctx, cfg, w["attn"], h, phase=phase,
                               cache=cache, length=length, tp=tp)
    x = x + o
    aux = 0.0
    h = _apply_norm(w["ln2"], x, cfg)
    if "moe" in w:
        mo, maux = moe_mod.moe_ffn(ctx, w["moe"], h, cfg,
                                   tokens_replicated=(phase == "decode"))
        aux = maux.load_balance + maux.z_loss
        if "dense_mlp" in w:
            hd = _apply_norm(w["ln3"], x, cfg)
            mo = mo + mlp_forward(ctx, w["dense_mlp"], hd, cfg, tp=tp)
        x = x + mo
    else:
        x = x + mlp_forward(ctx, w["mlp"], h, cfg, tp=tp)
    return x, new_cache, aux


def _take(tree, i):
    return jax.tree.map(lambda t: t[i], tree)


def _hybrid_period(ctx, cfg, w, x, *, phase, caches=None, length=None,
                   tp=False, mix: AxisCtx = None):
    """One jamba period (8 sublayers). caches: dict with 'attn' and 'mamba'."""
    P = cfg.hybrid.period
    new_caches = {"attn": None, "mamba": []}
    aux = 0.0
    mi = 0
    # sublayer-level remat: the period body is itself rematted by the outer
    # layer scan; without nested checkpoints its backward would re-save ALL
    # 7 mamba scans' + 4 MoE rings' residuals at once (hundreds of GiB).
    ckpt = jax.checkpoint if phase == "train" else (lambda f: f)
    mix = mix if mix is not None else ctx
    for i in range(P):
        h = rms_norm(x, w["ln_mix"]["w"][i], cfg.norm_eps)
        if i == cfg.hybrid.attn_index:
            o, nc = _attn_layer(mix, cfg, w["attn"], h, phase=phase,
                                cache=None if caches is None else caches["attn"],
                                length=length, tp=tp)
            new_caches["attn"] = nc
        else:
            wm = _take(w["mamba"], mi)
            st = None if caches is None else caches["mamba"][mi]
            if phase == "decode":
                o, st_new = ssm_mod.mamba_decode(wm, h, cfg, st, ctx=ctx, tp=tp)
            else:
                o, st_new = ckpt(lambda wm_, h_: ssm_mod.mamba_forward(
                    wm_, h_, cfg, state=None, ctx=mix))(wm, h)
            new_caches["mamba"].append(st_new)
            mi += 1
        x = x + o
        h = rms_norm(x, w["ln_ffn"]["w"][i], cfg.norm_eps)
        if i % cfg.moe.moe_every == cfg.moe.moe_offset:
            wmoe = _take(w["moe"], i // cfg.moe.moe_every)
            mo, maux = ckpt(lambda wm_, h_: moe_mod.moe_ffn(
                ctx, wm_, h_, cfg,
                tokens_replicated=(phase == "decode")))(wmoe, h)
            aux = aux + maux.load_balance + maux.z_loss
            x = x + mo
        else:
            wmlp = _take(w["mlp"], i // 2)
            x = x + mlp_forward(ctx, wmlp, h, cfg, tp=tp)
    return x, new_caches, aux


def _xlstm_period(ctx, cfg, w, x, *, phase, caches=None):
    """xLSTM period: 3 mLSTM + 1 sLSTM (all residual)."""
    new_caches = {"mlstm": [], "slstm": None}
    n_m = cfg.ssm.slstm_every - 1
    for i in range(n_m):
        h = rms_norm(x, w["ln"]["w"][i], cfg.norm_eps)
        st = None if caches is None else caches["mlstm"][i]
        o, st_new = ssm_mod.mlstm_forward(_take(w["mlstm"], i), h, cfg, state=st)
        new_caches["mlstm"].append(st_new)
        x = x + o
    h = rms_norm(x, w["ln"]["w"][n_m], cfg.norm_eps)
    st = None if caches is None else caches["slstm"]
    o, st_new = ssm_mod.slstm_forward(w["slstm"], h, cfg, state=st)
    new_caches["slstm"] = st_new
    x = x + o
    return x, new_caches, 0.0

# ---------------------------------------------------------------------------
# Layer-stack scanning (ZeRO-3 gather inside the scan body)
# ---------------------------------------------------------------------------

def seq_sharded_in(cfg: ModelConfig, phase: str) -> bool:
    """Whether the sequence dim is sharded over `model` in this phase.

    - ssm (xlstm): never — sLSTM/mLSTM recurrences cross shard boundaries.
    - hybrid (jamba): prefill only. In training the mamba cross-shard state
      handoff interacts badly with AD ((M,B,d,N) summaries become residuals),
      so train shards batch over (data x model) with full sequences instead.
    - all attention-only families: always (SP).
    """
    import os
    if cfg.family == "ssm":
        return False
    if cfg.family == "hybrid" and phase == "train":
        return False
    if phase == "train" and os.environ.get("REPRO_TRAIN_LAYOUT") == "dp2d":
        # beyond-paper layout: batch over (data x model), full sequences per
        # chip — no per-layer K/V all-gather (EXPERIMENTS.md §Perf, yi cell)
        return False
    return True


def mixer_ctx(ctx: AxisCtx, cfg: ModelConfig, phase: str) -> AxisCtx:
    """Ctx for token mixers: drops the model axis when sequences are local
    (keeps vocab sharding and the data/pod axes)."""
    if seq_sharded_in(cfg, phase):
        return ctx
    return dataclasses.replace(ctx, model=None, vocab=ctx.vaxis)


def _block_fn(cfg: ModelConfig):
    if cfg.family == "hybrid":
        return _hybrid_period
    if cfg.family == "ssm":
        return _xlstm_period
    return _dense_block


def stack_train(ctx, cfg, blocks_loc, x, gather_fn, *, phase="train",
                length=None, tp=False):
    """Forward through the scanned stack. phase='train' keeps only x (+aux);
    phase='prefill' additionally stacks per-layer caches."""
    fn = _block_fn(cfg)

    mix = mixer_ctx(ctx, cfg, phase)

    def body(carry, blk_loc):
        xc, aux = carry
        blk = gather_fn(blk_loc)
        if cfg.family == "ssm":
            xc, caches, a = fn(mix, cfg, blk, xc, phase=phase,
                               caches=None)
        elif cfg.family == "hybrid":
            xc, caches, a = fn(ctx, cfg, blk, xc, phase=phase, length=length,
                               tp=tp, mix=mix)
        else:
            xc, caches, a = fn(ctx, cfg, blk, xc, phase=phase, length=length,
                               tp=tp)
        ys = caches if phase == "prefill" else 0
        return (xc, aux + a), ys

    wrapped = jax.checkpoint(body) if phase == "train" else body
    (x, aux), caches = jax.lax.scan(wrapped, (x, 0.0), blocks_loc)
    return x, aux, caches


def stack_decode(ctx, cfg, blocks_loc, x, caches, length, gather_fn, *,
                 tp=True):
    fn = _block_fn(cfg)

    def body(xc, xs):
        blk_loc, cache = xs
        blk = gather_fn(blk_loc)
        if cfg.family == "ssm":
            xc, new_cache, _ = fn(ctx, cfg, blk, xc, phase="decode",
                                  caches=cache)
        elif cfg.family == "hybrid":
            xc, new_cache, _ = fn(ctx, cfg, blk, xc, phase="decode",
                                  caches=cache, length=length, tp=tp)
        else:
            xc, new_cache, _ = fn(ctx, cfg, blk, xc, phase="decode",
                                  cache=cache, length=length, tp=tp)
        return xc, new_cache

    x, new_caches = jax.lax.scan(body, x, (blocks_loc, caches))
    return x, new_caches


# ---------------------------------------------------------------------------
# Encoder-decoder (whisper) specifics
# ---------------------------------------------------------------------------

def _sinusoid(positions, D):
    half = D // 2
    freqs = np.exp(-np.log(10000.0) * np.arange(half) / half)
    ang = positions[:, None].astype(jnp.float32) * freqs[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)


def _cross_attn(ctx, cfg, w, x_dec, enc_k, enc_v, *, tp=False):
    """Cross-attention: q from decoder rows, K/V precomputed from encoder
    output (already gathered/global). Non-causal."""
    B, S_loc = x_dec.shape[0], x_dec.shape[1]
    H, HD = cfg.n_heads, cfg.resolved_head_dim
    q = attn.col_matmul(ctx, x_dec, w["wq"], w.get("bq"), tp)
    q = q.reshape(B, S_loc, H, HD)
    o = ops.flash_attention(q, enc_k, enc_v, 0, False)
    return attn.row_matmul(ctx, o.reshape(B, S_loc, H * HD), w["wo"], tp)


def _enc_kv(ctx, cfg, w, enc_out, *, gathered=True):
    """K/V of encoder output for cross-attention (global sequence)."""
    B = enc_out.shape[0]
    KV, HD = cfg.n_kv_heads, cfg.resolved_head_dim
    k = (enc_out @ w["wk"] + w.get("bk", 0)).reshape(B, -1, KV, HD)
    v = (enc_out @ w["wv"] + w.get("bv", 0)).reshape(B, -1, KV, HD)
    return k, v


def encoder_forward(ctx, cfg, enc_blocks_loc, frames, gather_fn):
    """frames: (B, S_loc, D) stub embeddings, sequence-sharded."""
    S_loc = frames.shape[1]
    pos = ctx.index(ctx.model) * S_loc + jnp.arange(S_loc)
    x = frames + _sinusoid(pos, cfg.d_model)[None].astype(frames.dtype)

    def body(xc, blk_loc):
        blk = gather_fn(blk_loc)
        h = _apply_norm(blk["ln1"], xc, cfg)
        o = attn.gqa_seqsharded(ctx, blk["attn"], h, cfg, causal=False)
        xc = xc + o
        h = _apply_norm(blk["ln2"], xc, cfg)
        xc = xc + mlp_forward(ctx, blk["mlp"], h, cfg)
        return xc, 0

    x, _ = jax.lax.scan(jax.checkpoint(body), x, enc_blocks_loc)
    return x


def encdec_train(ctx, cfg, params, batch, gather_fn):
    enc_out = encoder_forward(ctx, cfg, params["enc_blocks"], batch["frames"],
                              gather_fn)
    enc_out = _apply_norm(params["enc_final_norm"], enc_out, cfg)
    enc_full = ctx.all_gather(enc_out, ctx.model, axis=1)    # (B, S_enc, D)
    x = embed_lookup(ctx, params["embed"], batch["tokens"],
                     out_dtype=enc_out.dtype)

    def body(carry, blk_loc):
        xc, _ = carry
        blk = gather_fn(blk_loc)
        h = _apply_norm(blk["ln1"], xc, cfg)
        xc = xc + attn.gqa_seqsharded(ctx, blk["attn"], h, cfg)
        h = _apply_norm(blk["ln_x"], xc, cfg)
        ek, ev = _enc_kv(ctx, cfg, blk["xattn"], enc_full)
        xc = xc + _cross_attn(ctx, cfg, blk["xattn"], h, ek, ev)
        h = _apply_norm(blk["ln2"], xc, cfg)
        xc = xc + mlp_forward(ctx, blk["mlp"], h, cfg)
        return (xc, 0.0), 0

    (x, _), _ = jax.lax.scan(jax.checkpoint(body), (x, 0.0), params["blocks"])
    return x, enc_full


# ---------------------------------------------------------------------------
# Public Model API
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # -- construction -------------------------------------------------
    def init(self, key, dtype=jnp.float32):
        return init_params(key, self.cfg, dtype)

    def shapes(self):
        return param_shapes(self.cfg)

    # -- training ------------------------------------------------------
    def loss(self, ctx: AxisCtx, params, batch, gather_fn=lambda b: b):
        cfg = self.cfg
        if cfg.family == "encdec":
            x, _ = encdec_train(ctx, cfg, params, batch, gather_fn)
            aux = 0.0
        else:
            emb = params["embed"]
            if cfg.tie_embeddings:
                emb_full = ctx.all_gather(emb, ctx.vaxis, axis=0)
                x = embed_lookup(ctx, emb_full, batch["tokens"], tied=True)
            else:
                x = embed_lookup(ctx, emb, batch["tokens"])
            x, aux, _ = stack_train(ctx, cfg, params["blocks"], x, gather_fn)
        x = _apply_norm(params["final_norm"], x, cfg)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = (x @ head.astype(x.dtype)).astype(jnp.float32)
        loss = softmax_xent_vshard(ctx, logits, batch["labels"])
        aux = ctx.pmean(aux, tuple(a for a in (ctx.pod, ctx.data, ctx.model) if a))
        return loss + aux, {"loss": loss, "aux": aux}

    # -- serving -------------------------------------------------------
    def prefill(self, ctx: AxisCtx, params, batch, gather_fn=lambda b: b):
        """Returns (caches, last_logits, length)."""
        cfg = self.cfg
        if cfg.tie_embeddings:
            emb_full = ctx.all_gather(params["embed"], ctx.vaxis, axis=0)
            x = embed_lookup(ctx, emb_full, batch["tokens"], tied=True)
        else:
            x = embed_lookup(ctx, params["embed"], batch["tokens"])
        x, _, caches = stack_train(ctx, cfg, params["blocks"], x, gather_fn,
                                   phase="prefill")
        x = _apply_norm(params["final_norm"], x, cfg)
        last = x[:, -1:]
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = (last @ head.astype(last.dtype)).astype(jnp.float32)
        if ctx.model is not None:
            M = ctx.size(ctx.model)
            is_last = (ctx.index(ctx.model) == M - 1).astype(jnp.float32)
            logits = ctx.psum(logits * is_last, ctx.model)
        return caches, logits[:, 0], None

    def decode_step(self, ctx: AxisCtx, params, tokens, caches, length,
                    gather_fn=lambda b: b, *, tp=True):
        """tokens: (B,) previous token ids; length: (B,) context length.
        Returns (logits_loc (B, V_loc), new_caches)."""
        cfg = self.cfg
        x = embed_lookup(ctx, params["embed"], tokens[:, None],
                         tied=cfg.tie_embeddings, tokens_replicated=True)
        x, new_caches = stack_decode(ctx, cfg, params["blocks"], x, caches,
                                     length, gather_fn, tp=tp)
        x = _apply_norm(params["final_norm"], x, cfg)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = (x @ head.astype(x.dtype)).astype(jnp.float32)
        return logits[:, 0], new_caches

    def greedy_token(self, ctx: AxisCtx, logits_loc):
        """Global argmax over the vocab-sharded logits. (B, V_loc) -> (B,)."""
        V_loc = logits_loc.shape[-1]
        off = ctx.index(ctx.vaxis) * V_loc
        idx = jnp.argmax(logits_loc, -1)
        val = jnp.take_along_axis(logits_loc, idx[:, None], 1)[:, 0]
        if ctx.vaxis is None:
            return idx
        both = jnp.stack([val, (idx + off).astype(val.dtype)], -1)  # (B, 2)
        allv = ctx.all_gather(both[None], ctx.vaxis, axis=0)        # (M, B, 2)
        best = jnp.argmax(allv[..., 0], axis=0)                     # (B,)
        return jnp.take_along_axis(
            allv[..., 1], best[None], 0)[0].astype(jnp.int32)


def pad_caches(caches, extra: int):
    """Grow attention caches by ``extra`` sequence slots (recurrent SSM states
    are position-free and pass through untouched).

    Note: valid for unsharded or data-only-sharded caches. A sequence-sharded
    cache (model axis) has a fixed per-shard block layout — size the capacity
    at prefill time instead (see launch/serve.py).
    """
    kinds = (attn.KVCache, attn.LatentCache)

    def fix(leaf):
        if isinstance(leaf, attn.KVCache) or isinstance(leaf, attn.LatentCache):
            return type(leaf)(*[
                jnp.pad(t, [(0, 0)] * 2 + [(0, extra)] + [(0, 0)] * (t.ndim - 3))
                for t in leaf])
        return leaf

    return jax.tree.map(fix, caches, is_leaf=lambda x: isinstance(x, kinds))


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "encdec":
        return EncDecModel(cfg)
    return Model(cfg)


class EncDecCaches(NamedTuple):
    self_caches: Any          # stacked KVCache over decoder layers
    cross_k: Any              # (L, B, S_loc, KV, HD) sequence-sharded
    cross_v: Any


@dataclasses.dataclass(frozen=True)
class EncDecModel(Model):
    def loss(self, ctx, params, batch, gather_fn=lambda b: b):
        cfg = self.cfg
        x, _ = encdec_train(ctx, cfg, params, batch, gather_fn)
        x = _apply_norm(params["final_norm"], x, cfg)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = (x @ head.astype(x.dtype)).astype(jnp.float32)
        loss = softmax_xent_vshard(ctx, logits, batch["labels"])
        return loss, {"loss": loss, "aux": 0.0}

    def prefill(self, ctx, params, batch, gather_fn=lambda b: b):
        """Encoder forward + decoder prefill over the prompt tokens."""
        cfg = self.cfg
        enc_out = encoder_forward(ctx, cfg, params["enc_blocks"],
                                  batch["frames"], gather_fn)
        enc_out = _apply_norm(params["enc_final_norm"], enc_out, cfg)
        enc_full = ctx.all_gather(enc_out, ctx.model, axis=1)
        x = embed_lookup(ctx, params["embed"], batch["tokens"],
                         out_dtype=enc_out.dtype)

        def body(xc, blk_loc):
            blk = gather_fn(blk_loc)
            h = _apply_norm(blk["ln1"], xc, cfg)
            o, cache = attn.gqa_seqsharded(ctx, blk["attn"], h, cfg,
                                           return_cache=True)
            xc = xc + o
            h = _apply_norm(blk["ln_x"], xc, cfg)
            ek, ev = _enc_kv(ctx, cfg, blk["xattn"], enc_full)
            xc = xc + _cross_attn(ctx, cfg, blk["xattn"], h, ek, ev)
            h = _apply_norm(blk["ln2"], xc, cfg)
            xc = xc + mlp_forward(ctx, blk["mlp"], h, cfg)
            # store the *local* slice of cross K/V (seq-sharded cache)
            ck, cv = _enc_kv(ctx, cfg, blk["xattn"], enc_out)
            return xc, (cache, ck, cv)

        x, (self_caches, cross_k, cross_v) = jax.lax.scan(
            body, x, params["blocks"])
        x = _apply_norm(params["final_norm"], x, cfg)
        last = x[:, -1:]
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = (last @ head.astype(last.dtype)).astype(jnp.float32)
        if ctx.model is not None:
            M = ctx.size(ctx.model)
            is_last = (ctx.index(ctx.model) == M - 1).astype(jnp.float32)
            logits = ctx.psum(logits * is_last, ctx.model)
        return EncDecCaches(self_caches, cross_k, cross_v), logits[:, 0], None

    def decode_step(self, ctx, params, tokens, caches, length,
                    gather_fn=lambda b: b, *, tp=True):
        cfg = self.cfg
        x = embed_lookup(ctx, params["embed"], tokens[:, None])
        S_enc_loc = caches.cross_k.shape[2]
        enc_len = jnp.full((x.shape[0],),
                           S_enc_loc * max(ctx.size(ctx.model), 1), jnp.int32)

        def body(xc, xs):
            blk_loc, cache, ck, cv = xs
            blk = gather_fn(blk_loc)
            h = _apply_norm(blk["ln1"], xc, cfg)
            o, new_cache = attn.gqa_decode(ctx, blk["attn"], h, cache, length,
                                           cfg, tp=tp)
            xc = xc + o
            # cross-attention over the sequence-sharded encoder cache
            h = _apply_norm(blk["ln_x"], xc, cfg)
            H, HD = cfg.n_heads, cfg.resolved_head_dim
            q = attn.col_matmul(ctx, h, blk["xattn"]["wq"],
                                blk["xattn"].get("bq"), tp)
            q = q.reshape(xc.shape[0], H, HD)
            loc_len = jnp.full_like(length, S_enc_loc)
            o2, m2, l2 = ops.decode_attention(q, ck, cv, loc_len, combine=False)
            if ctx.model is not None:
                B = xc.shape[0]
                stats = jnp.concatenate([o2.reshape(B, -1), m2, l2], -1)
                g = ctx.all_gather(stats[None], ctx.model, axis=0)
                o_all = g[..., :H * HD].reshape(-1, B, H, HD)
                m_all = g[..., H * HD:H * HD + H].reshape(-1, B, H)
                l_all = g[..., H * HD + H:].reshape(-1, B, H)
                mg = m_all.max(0)
                wgt = jnp.exp(m_all - mg[None])
                lg = (l_all * wgt).sum(0)
                o2 = (o_all * wgt[..., None]).sum(0) / jnp.maximum(
                    lg, 1e-30)[..., None]
            else:
                o2 = o2 / jnp.maximum(l2, 1e-30)[..., None]
            o2 = attn.row_matmul(ctx, o2.astype(xc.dtype).reshape(
                xc.shape[0], 1, H * HD), blk["xattn"]["wo"], tp)
            xc = xc + o2
            h = _apply_norm(blk["ln2"], xc, cfg)
            xc = xc + mlp_forward(ctx, blk["mlp"], h, cfg, tp=tp)
            return xc, new_cache

        x, new_self = jax.lax.scan(
            body, x, (params["blocks"], caches.self_caches,
                      caches.cross_k, caches.cross_v))
        x = _apply_norm(params["final_norm"], x, cfg)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = (x @ head.astype(x.dtype)).astype(jnp.float32)
        return logits[:, 0], EncDecCaches(new_self, caches.cross_k,
                                          caches.cross_v)
