"""Attention blocks: GQA (+ QKV bias, qk-norm) and MLA, for three phases.

Phases and their sharding (see DESIGN.md):
- train / prefill: activations sequence-sharded over ``model`` (SP); weights
  arrive fully gathered (ZeRO-3 gather happens in transformer.py). Each chip
  runs blockwise flash attention over its local q rows with K/V all-gathered
  along the sequence — positions are offset by ``axis_index('model') * S_loc``.
- decode: weights are TP-resident and activations replicated over ``model``;
  the KV cache is sequence-sharded over ``model`` and partial attention
  results are log-sum-exp combined (chunk-parallel decode).

All functions take an ``AxisCtx``: with a no-axis ctx they are ordinary
single-device attention (the test oracle).
"""
from __future__ import annotations

import os
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models.layers import apply_rope, dense_init, rms_norm
from repro.sharding.axes import AxisCtx


class KVCache(NamedTuple):
    """Sequence-sharded KV cache. k/v: (B, S_loc, KV, D); length: (B,) global."""
    k: jnp.ndarray
    v: jnp.ndarray


class LatentCache(NamedTuple):
    """MLA cache: compressed kv latent + shared rope key. (B, S_loc, R)"""
    ckv: jnp.ndarray
    krope: jnp.ndarray


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def gqa_param_shapes(cfg: ModelConfig) -> dict:
    D, H, KV, HD = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    shapes = {
        "wq": (D, H * HD),
        "wk": (D, KV * HD),
        "wv": (D, KV * HD),
        "wo": (H * HD, D),
    }
    if cfg.qkv_bias:
        shapes |= {"bq": (H * HD,), "bk": (KV * HD,), "bv": (KV * HD,)}
    if cfg.qk_norm:
        shapes |= {"q_norm": (HD,), "k_norm": (HD,)}
    return shapes


def mla_param_shapes(cfg: ModelConfig) -> dict:
    m, D, H = cfg.mla, cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wdq": (D, m.q_lora_rank),
        "q_norm": (m.q_lora_rank,),
        "wuq": (m.q_lora_rank, H * qk),
        "wdkv": (D, m.kv_lora_rank + m.qk_rope_head_dim),
        "kv_norm": (m.kv_lora_rank,),
        "wukv": (m.kv_lora_rank, H * (m.qk_nope_head_dim + m.v_head_dim)),
        "wo": (H * m.v_head_dim, D),
    }


def attn_param_shapes(cfg: ModelConfig) -> dict:
    return mla_param_shapes(cfg) if cfg.attn_type == "mla" else gqa_param_shapes(cfg)


def init_attn_params(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    shapes = attn_param_shapes(cfg)
    keys = jax.random.split(key, len(shapes))
    out = {}
    for (name, shape), k in zip(sorted(shapes.items()), keys):
        if name.endswith("_norm"):
            out[name] = jnp.ones(shape, dtype)
        elif name.startswith("b"):
            out[name] = jnp.zeros(shape, dtype)
        else:
            out[name] = dense_init(k, shape, in_dim=shape[0], dtype=dtype)
    return out


# ---------------------------------------------------------------------------
# Tensor-parallel matmul helpers (decode phase: weights resident, acts tiny)
# ---------------------------------------------------------------------------

def col_matmul(ctx: AxisCtx, h, w_loc, b_loc=None, tp: bool = False):
    """Column-parallel y = h @ W (+b), output all-gathered to full width."""
    y = h @ w_loc
    if b_loc is not None:
        y = y + b_loc
    if tp and ctx.model is not None:
        y = ctx.all_gather(y, ctx.model, axis=y.ndim - 1)
    return y


def row_matmul(ctx: AxisCtx, h, w_loc, tp: bool = False):
    """Row-parallel y = h @ W with h full-width: slice local rows, psum."""
    if tp and ctx.model is not None:
        n = w_loc.shape[0]
        idx = ctx.index(ctx.model)
        h_loc = jax.lax.dynamic_slice_in_dim(h, idx * n, n, axis=h.ndim - 1)
        return ctx.psum(h_loc @ w_loc, ctx.model)
    return h @ w_loc


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def _qkv(w, cfg: ModelConfig, h):
    H, KV, HD = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    B, S = h.shape[0], h.shape[1]
    q = h @ w["wq"]
    k = h @ w["wk"]
    v = h @ w["wv"]
    if cfg.qkv_bias:
        q, k, v = q + w["bq"], k + w["bk"], v + w["bv"]
    q = q.reshape(B, S, H, HD)
    k = k.reshape(B, S, KV, HD)
    v = v.reshape(B, S, KV, HD)
    if cfg.qk_norm:
        q = rms_norm(q, w["q_norm"], cfg.norm_eps)
        k = rms_norm(k, w["k_norm"], cfg.norm_eps)
    return q, k, v


def gqa_seqsharded(ctx: AxisCtx, w: dict, h, cfg: ModelConfig,
                   *, causal: bool = True, return_cache: bool = False):
    """Train/prefill attention on the sequence-sharded residual stream.

    h: (B, S_loc, D) — local sequence rows; K/V are all-gathered over ``model``.
    Returns (B, S_loc, H*HD) [+ KVCache of the *local* rows].
    """
    S_loc = h.shape[1]
    q, k, v = _qkv(w, cfg, h)
    off = ctx.index(ctx.model) * S_loc
    pos_loc = off + jnp.arange(S_loc)
    q = apply_rope(q, pos_loc, cfg.rope_theta)
    k = apply_rope(k, pos_loc, cfg.rope_theta)
    cache = KVCache(k, v) if return_cache else None
    kg = ctx.all_gather(k, ctx.model, axis=1)
    vg = ctx.all_gather(v, ctx.model, axis=1)
    o = ops.flash_attention(q, kg, vg, off, causal)
    o = o.reshape(h.shape[0], S_loc, -1)
    out = o @ w["wo"]
    return (out, cache) if return_cache else out


def gqa_decode(ctx: AxisCtx, w: dict, h, cache: KVCache, length,
               cfg: ModelConfig, *, tp: bool = False):
    """One-token decode with a sequence-sharded cache.

    h: (B, 1, D) replicated over ``model``; cache.k/v: (B, S_loc, KV, HD)
    holding global positions [idx*S_loc, (idx+1)*S_loc); length: (B,) current
    context length (the new token goes to position ``length``). With
    ``tp=True`` the projections are column/row-parallel over ``model``
    (weights resident; only token-sized activations cross the ICI).
    Returns (out (B, 1, D), new_cache).
    """
    B = h.shape[0]
    H, KV, HD = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = col_matmul(ctx, h, w["wq"], w.get("bq"), tp).reshape(B, 1, H, HD)
    k_new = col_matmul(ctx, h, w["wk"], w.get("bk"), tp).reshape(B, 1, KV, HD)
    v_new = col_matmul(ctx, h, w["wv"], w.get("bv"), tp).reshape(B, 1, KV, HD)
    if cfg.qk_norm:
        q = rms_norm(q, w["q_norm"], cfg.norm_eps)
        k_new = rms_norm(k_new, w["k_norm"], cfg.norm_eps)
    pos = length[:, None]                                    # (B, 1)
    q = apply_rope(q, pos, cfg.rope_theta)
    k_new = apply_rope(k_new, pos, cfg.rope_theta)

    # scatter the new K/V row into the owning shard
    S_loc = cache.k.shape[1]
    start = ctx.index(ctx.model) * S_loc
    local_idx = jnp.clip(pos[:, 0] - start, 0, S_loc - 1)    # (B,)
    mine = (pos[:, 0] >= start) & (pos[:, 0] < start + S_loc)
    onehot = (jax.nn.one_hot(local_idx, S_loc, dtype=cache.k.dtype)
              * mine[:, None].astype(cache.k.dtype))         # (B, S_loc)
    k = cache.k + onehot[:, :, None, None] * k_new
    v = cache.v + onehot[:, :, None, None] * v_new

    # chunk-parallel attention: local partials, then LSE combine over model
    local_len = jnp.clip(length + 1 - start, 0, S_loc)
    o, m, l = ops.decode_attention(q[:, 0], k, v, local_len, combine=False)
    if ctx.model is not None:
        stats = jnp.concatenate(
            [o.reshape(B, -1), m.reshape(B, -1), l.reshape(B, -1)], axis=-1)
        gathered = ctx.all_gather(stats[None], ctx.model, axis=0)  # (M, B, ...)
        HDv = o.shape[-1]
        o_all = gathered[..., :H * HDv].reshape(-1, B, H, HDv)
        m_all = gathered[..., H * HDv:H * HDv + H].reshape(-1, B, H)
        l_all = gathered[..., H * HDv + H:].reshape(-1, B, H)
        m_g = m_all.max(0)
        w_ = jnp.exp(m_all - m_g[None])
        l_g = (l_all * w_).sum(0)
        o = (o_all * w_[..., None]).sum(0) / jnp.maximum(l_g, 1e-30)[..., None]
    else:
        o = o / jnp.maximum(l, 1e-30)[..., None]
    out = row_matmul(ctx, o.astype(h.dtype).reshape(B, 1, -1), w["wo"], tp)
    return out, KVCache(k, v)


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention)
# ---------------------------------------------------------------------------

def _mla_q(w, cfg, h, positions):
    m, H = cfg.mla, cfg.n_heads
    B, S = h.shape[0], h.shape[1]
    nope, rope_d = m.qk_nope_head_dim, m.qk_rope_head_dim
    cq = rms_norm(h @ w["wdq"], w["q_norm"], cfg.norm_eps)
    q = (cq @ w["wuq"]).reshape(B, S, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_kv_latent(w, cfg, h, positions):
    m = cfg.mla
    dkv = h @ w["wdkv"]                                       # (B,S,R+rope)
    ckv = rms_norm(dkv[..., :m.kv_lora_rank], w["kv_norm"], cfg.norm_eps)
    krope = dkv[..., m.kv_lora_rank:]                         # (B,S,rope)
    krope = apply_rope(krope[:, :, None, :], positions,
                       cfg.rope_theta)[:, :, 0, :]
    return ckv, krope


def _mla_expand_kv(w, cfg, ckv):
    m, H = cfg.mla, cfg.n_heads
    B, S = ckv.shape[0], ckv.shape[1]
    kv = (ckv @ w["wukv"]).reshape(B, S, H, m.qk_nope_head_dim + m.v_head_dim)
    return kv[..., :m.qk_nope_head_dim], kv[..., m.qk_nope_head_dim:]


def mla_seqsharded(ctx: AxisCtx, w: dict, h, cfg: ModelConfig,
                   *, causal: bool = True, return_cache: bool = False):
    """MLA train/prefill.

    Two algebraically identical forms (EXPERIMENTS.md §Perf):
    - expanded (REPRO_MLA_ABSORBED=0): materialize per-head K/V from the
      latent — matmul-friendly but writes/reads H*(Dk+Dv)-wide tensors;
    - absorbed (default): fold W^UK into the queries and attend in the
      latent space as MQA with one 288-wide shared KV head; W^UV is applied
      to the 256-wide latent output. More attention FLOPs (R=256 > 160),
      ~5x less attention HBM traffic — the right trade on TPU where the MLA
      layers are memory-bound."""
    m, H = cfg.mla, cfg.n_heads
    B, S_loc = h.shape[0], h.shape[1]
    off = ctx.index(ctx.model) * S_loc
    pos_loc = off + jnp.arange(S_loc)
    q_nope, q_rope = _mla_q(w, cfg, h, pos_loc)
    ckv, krope = _mla_kv_latent(w, cfg, h, pos_loc)
    cache = LatentCache(ckv, krope) if return_cache else None
    ckv_g = ctx.all_gather(ckv, ctx.model, axis=1)
    krope_g = ctx.all_gather(krope, ctx.model, axis=1)
    scale = 1.0 / np.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    R, nope = m.kv_lora_rank, m.qk_nope_head_dim
    if os.environ.get("REPRO_MLA_ABSORBED", "1") == "1":
        wukv = w["wukv"].reshape(R, H, nope + m.v_head_dim)
        q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, wukv[..., :nope])
        q_cat = jnp.concatenate([q_lat, q_rope], -1)       # (B,S,H,R+rope)
        kv_cat = jnp.concatenate([ckv_g, krope_g], -1)[:, :, None, :]
        o_lat = ops.flash_attention(q_cat, kv_cat, ckv_g[:, :, None, :],
                                    off, causal, scale)    # (B,S,H,R)
        o = jnp.einsum("bshr,rhv->bshv", o_lat, wukv[..., nope:])
    else:
        k_nope, v = _mla_expand_kv(w, cfg, ckv_g)
        q = jnp.concatenate([q_nope, q_rope], -1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(krope_g[:, :, None, :],
                                      k_nope.shape[:3] + (m.qk_rope_head_dim,))],
            -1)
        o = ops.flash_attention(q, k, v, off, causal, scale)
    out = o.reshape(B, S_loc, -1) @ w["wo"]
    return (out, cache) if return_cache else out


def mla_decode(ctx: AxisCtx, w: dict, h, cache: LatentCache, length,
               cfg: ModelConfig, *, tp: bool = False):
    """Absorbed-form MLA decode: attention runs in the latent space, so the
    per-step FLOPs scale with kv_lora_rank (288) instead of H*(Dk+Dv).

    MLA decode keeps its (small) attention weights replicated over ``model``
    (``tp`` only affects the surrounding FFN; see sharding/specs.py) — the
    absorbed einsums are not head-shardable for H % mesh != 0."""
    m, H = cfg.mla, cfg.n_heads
    B = h.shape[0]
    R, rope_d, nope = m.kv_lora_rank, m.qk_rope_head_dim, m.qk_nope_head_dim
    pos = length[:, None]
    q_nope, q_rope = _mla_q(w, cfg, h, pos)                   # (B,1,H,*)
    ckv_new, krope_new = _mla_kv_latent(w, cfg, h, pos)       # (B,1,R)/(B,1,rope)

    S_loc = cache.ckv.shape[1]
    start = ctx.index(ctx.model) * S_loc
    local_idx = jnp.clip(pos[:, 0] - start, 0, S_loc - 1)
    mine = (pos[:, 0] >= start) & (pos[:, 0] < start + S_loc)
    onehot = (jax.nn.one_hot(local_idx, S_loc, dtype=cache.ckv.dtype)
              * mine[:, None].astype(cache.ckv.dtype))
    ckv = cache.ckv + onehot[..., None] * ckv_new
    krope = cache.krope + onehot[..., None] * krope_new

    # absorb W^UK into q: q_lat (B,H,R) = q_nope @ Wuk_h^T
    wukv = w["wukv"].reshape(R, H, nope + m.v_head_dim)
    wuk = wukv[..., :nope]                                    # (R,H,nope)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], wuk)

    # latent-space attention over the local shard
    scale = 1.0 / np.sqrt(nope + rope_d)
    s = (jnp.einsum("bhr,bsr->bhs", q_lat, ckv)
         + jnp.einsum("bhd,bsd->bhs", q_rope[:, 0], krope)).astype(jnp.float32)
    s = s * scale
    local_len = jnp.clip(length + 1 - start, 0, S_loc)
    valid = jnp.arange(S_loc)[None] < local_len[:, None]
    s = jnp.where(valid[:, None], s, -1e30)
    m_ = s.max(-1)
    p = jnp.exp(s - m_[..., None])
    l = p.sum(-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", p.astype(ckv.dtype), ckv)

    if ctx.model is not None:
        stats = jnp.concatenate(
            [o_lat.reshape(B, -1).astype(jnp.float32),
             m_.reshape(B, -1), l.reshape(B, -1)], -1)
        gathered = ctx.all_gather(stats[None], ctx.model, axis=0)
        o_all = gathered[..., :H * R].reshape(-1, B, H, R)
        m_all = gathered[..., H * R:H * R + H].reshape(-1, B, H)
        l_all = gathered[..., H * R + H:].reshape(-1, B, H)
        m_g = m_all.max(0)
        w_ = jnp.exp(m_all - m_g[None])
        l_g = (l_all * w_).sum(0)
        o_lat = ((o_all * w_[..., None]).sum(0)
                 / jnp.maximum(l_g, 1e-30)[..., None])
    else:
        o_lat = o_lat.astype(jnp.float32) / jnp.maximum(l, 1e-30)[..., None]

    # expand through W^UV: o (B,H,v_dim)
    wuv = wukv[..., nope:]                                    # (R,H,v)
    o = jnp.einsum("bhr,rhv->bhv", o_lat.astype(h.dtype), wuv)
    out = o.reshape(B, 1, -1) @ w["wo"]
    return out, LatentCache(ckv, krope)


# ---------------------------------------------------------------------------
# Cache initialization
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, s_loc: int, dtype=jnp.bfloat16):
    if cfg.attn_type == "mla":
        m = cfg.mla
        return LatentCache(
            ckv=jnp.zeros((batch, s_loc, m.kv_lora_rank), dtype),
            krope=jnp.zeros((batch, s_loc, m.qk_rope_head_dim), dtype))
    HD = cfg.resolved_head_dim
    return KVCache(
        k=jnp.zeros((batch, s_loc, cfg.n_kv_heads, HD), dtype),
        v=jnp.zeros((batch, s_loc, cfg.n_kv_heads, HD), dtype))
