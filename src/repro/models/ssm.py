"""State-space / recurrent blocks: Mamba (S6 selective scan) and xLSTM.

TPU adaptation: the CUDA selective-scan kernel the Mamba paper ships has no
TPU analogue — we use a *chunked* scan: within a chunk of Q timesteps the
recurrence is materialized with cumulative log-decays (VMEM-sized tensors,
MXU-friendly einsums); chunks are threaded sequentially via ``lax.scan`` with
an (B, d_inner, N) carry. The mLSTM uses the same chunkwise-parallel trick
(matrix memory carried across chunks); the sLSTM is an inherently sequential
``lax.scan`` over time (that is its nature per the xLSTM paper).

All blocks support decode (single-step recurrence with carried state), which
is what makes these archs run long_500k (state size independent of context).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init

# ---------------------------------------------------------------------------
# Mamba (S6)
# ---------------------------------------------------------------------------


class MambaState(NamedTuple):
    h: jnp.ndarray        # (B, d_inner, N) ssm state
    conv: jnp.ndarray     # (B, d_conv - 1, d_inner) rolling conv inputs


def mamba_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = int(s.expand * cfg.d_model)
    dt_rank = s.dt_rank or max(1, cfg.d_model // 16)
    return d_inner, dt_rank, s.d_state, s.d_conv


def mamba_param_shapes(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    d_inner, dt_rank, N, d_conv = mamba_dims(cfg)
    return {
        "in_proj_x": (D, d_inner),
        "in_proj_z": (D, d_inner),
        "conv_w": (d_conv, d_inner),
        "conv_b": (d_inner,),
        "x_proj": (d_inner, dt_rank + 2 * N),
        "dt_proj": (dt_rank, d_inner),
        "dt_bias": (d_inner,),
        "A_log": (d_inner, N),
        "D_skip": (d_inner,),
        "out_proj": (d_inner, D),
    }


def init_mamba_params(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    shapes = mamba_param_shapes(cfg)
    ks = jax.random.split(key, len(shapes))
    d_inner, dt_rank, N, _ = mamba_dims(cfg)
    out = {}
    for (name, shape), k in zip(sorted(shapes.items()), ks):
        if name == "A_log":
            out[name] = jnp.log(jnp.broadcast_to(
                jnp.arange(1, N + 1, dtype=jnp.float32), shape)).astype(dtype)
        elif name == "D_skip":
            out[name] = jnp.ones(shape, dtype)
        elif name in ("conv_b", "dt_bias"):
            out[name] = jnp.zeros(shape, dtype)
        else:
            out[name] = dense_init(k, shape, in_dim=shape[0], dtype=dtype)
    return out


def _mamba_chunk(h0, xc, dtc, Bc, Cc, A):
    """One chunk of the selective scan via a stable associative scan.

    h0: (B, d, N); xc: (B, Q, d); dtc: (B, Q, d); Bc/Cc: (B, Q, N); A: (d, N).
    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ;  y_t = <C_t, h_t>.
    Decay factors a_t = exp(dt_t A) are in (0, 1], so the associative combine
    (a_l a_r, b_l a_r + b_r) never overflows (unlike cumulative log-decay
    ratios, which blow up past ~exp(88) in f32).
    """
    a = jnp.exp(dtc[..., None] * A[None, None])             # (B,Q,d,N) in (0,1]
    b = jnp.einsum("bqd,bqn->bqdn", dtc * xc, Bc)           # (B,Q,d,N)

    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    aa, bb = jax.lax.associative_scan(comb, (a, b), axis=1)
    h_all = bb + aa * h0[:, None]                           # (B,Q,d,N)
    y = jnp.einsum("bqdn,bqn->bqd", h_all, Cc)
    return h_all[:, -1], y


def mamba_forward(w: dict, x, cfg: ModelConfig, state: MambaState | None = None,
                  ctx=None, tp: bool = False):
    """x: (B, S, D). Returns (y (B,S,D), final MambaState). f32 scan math.

    Sequence sharding (``ctx`` with a model axis, tp=False): the recurrence
    crosses shard boundaries, handled in two linear passes — (1) local scan
    with h0=0, (2) exchange per-shard (h_last, total-decay) summaries
    (all-gather, KBs) and add the correction ``C_t exp(cum_t) h0_true`` by
    re-running the chunk scan with zero inputs. The depthwise conv gets its
    boundary rows from the left neighbour via ppermute.

    TP decode (tp=True): d_inner is model-sharded (column weights local);
    x_proj / out_proj are row-parallel with tiny psums.
    """
    s = cfg.ssm
    B, S, D = x.shape
    d_inner, dt_rank, N, d_conv = mamba_dims(cfg)
    # chunk sized so the (B, Q, d, N) scan transient stays ~<=128 MB f32
    budget = max(1, (32 * 1024 * 1024) // max(1, B * d_inner * N))
    Q = min(s.chunk, S, budget)
    while S % Q:
        Q -= 1
    seq_sharded = (ctx is not None and ctx.model is not None and not tp
                   and state is None)

    xi = x @ w["in_proj_x"]
    z = x @ w["in_proj_z"]
    d_loc = xi.shape[-1]                                     # d_inner or /M

    # depthwise causal conv over time (boundary rows from left neighbour)
    if state is not None:
        prev = state.conv.astype(xi.dtype)
    elif seq_sharded:
        M = ctx.size(ctx.model)
        tail = xi[:, -(d_conv - 1):]
        prev = ctx.ppermute(tail, ctx.model,
                            [(i, i + 1) for i in range(M - 1)])
    else:
        prev = jnp.zeros((B, d_conv - 1, d_loc), xi.dtype)
    xpad = jnp.concatenate([prev, xi], axis=1)
    conv = sum(xpad[:, i:i + S] * w["conv_w"][i][None, None]
               for i in range(d_conv))
    xi = jax.nn.silu(conv + w["conv_b"])
    new_conv = xpad[:, -(d_conv - 1):]                       # rolling window

    # input-dependent dt, B, C
    proj = (xi @ w["x_proj"]).astype(jnp.float32)
    if tp and ctx is not None:
        proj = ctx.psum(proj, ctx.model)                     # row-parallel
    dt = jax.nn.softplus(proj[..., :dt_rank] @ w["dt_proj"].astype(jnp.float32)
                         + w["dt_bias"].astype(jnp.float32))  # (B,S,d_loc)
    Bmat = proj[..., dt_rank:dt_rank + N]
    Cmat = proj[..., dt_rank + N:]
    A = -jnp.exp(w["A_log"].astype(jnp.float32))             # (d_loc,N)

    xif = xi.astype(jnp.float32)
    h0 = (jnp.zeros((B, d_loc, N), jnp.float32)
          if state is None else state.h.astype(jnp.float32))

    nchunk = S // Q
    xc = jnp.moveaxis(xif.reshape(B, nchunk, Q, d_loc), 1, 0)
    dtc = jnp.moveaxis(dt.reshape(B, nchunk, Q, d_loc), 1, 0)
    Bc = jnp.moveaxis(Bmat.reshape(B, nchunk, Q, N), 1, 0)
    Cc = jnp.moveaxis(Cmat.reshape(B, nchunk, Q, N), 1, 0)

    def step(h, xs):
        xq, dtq, bq, cq = xs
        h_new, y = _mamba_chunk(h, xq, dtq, bq, cq, A)
        return h_new, y

    # remat the chunk body: scan-AD then saves only the (B,d,N) carry per
    # chunk instead of the (B,Q,d,N) associative-scan internals.
    step = jax.checkpoint(step)
    h_fin, ys = jax.lax.scan(step, h0, (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, d_loc)

    if seq_sharded:
        # cross-shard state handoff: shard m needs h0 = state after shard m-1
        M = ctx.size(ctx.model)
        logdecay_tot = dt.sum(axis=1)[..., None] * A[None]   # (B,d,N)
        summ = jnp.stack([h_fin, logdecay_tot], 0)           # (2,B,d,N)
        allsum = ctx.all_gather(summ[None], ctx.model, axis=0)  # (M,2,B,d,N)

        def combine(carry, sm):
            h_run = carry
            h_last_j, ld_j = sm[0], sm[1]
            out = h_run                                       # h0 for shard j
            h_run = jnp.exp(ld_j) * h_run + h_last_j
            return h_run, out

        h_run, h0s = jax.lax.scan(combine, jnp.zeros_like(h_fin), allsum)
        h0_true = h0s[ctx.index(ctx.model)]
        # correction pass: same scan with zero inputs picks up C_t e^{cum} h0
        _, ys_corr = jax.lax.scan(step, h0_true,
                                  (jnp.zeros_like(xc), dtc, Bc, Cc))
        y = y + jnp.moveaxis(ys_corr, 0, 1).reshape(B, S, d_loc)
        h_fin = h_run                                         # global final

    y = y + xif * w["D_skip"].astype(jnp.float32)
    y = (y.astype(x.dtype) * jax.nn.silu(z))
    out = y @ w["out_proj"]
    if tp and ctx is not None:
        out = ctx.psum(out, ctx.model)
    return out, MambaState(h_fin.astype(jnp.float32), new_conv.astype(x.dtype))


def mamba_decode(w: dict, x, cfg: ModelConfig, state: MambaState,
                 ctx=None, tp: bool = False):
    """Single-token step. x: (B, 1, D); state channels model-sharded when tp."""
    return mamba_forward(w, x, cfg, state=state, ctx=ctx, tp=tp)


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (chunkwise-parallel) and sLSTM (sequential)
# ---------------------------------------------------------------------------


class MLSTMState(NamedTuple):
    C: jnp.ndarray        # (B, H, dv, dk) matrix memory
    n: jnp.ndarray        # (B, H, dk) normalizer
    m: jnp.ndarray        # (B, H) max-stabilizer


class SLSTMState(NamedTuple):
    c: jnp.ndarray        # (B, d)
    n: jnp.ndarray        # (B, d)
    h: jnp.ndarray        # (B, d)
    m: jnp.ndarray        # (B, d)


def xlstm_dims(cfg: ModelConfig):
    d_in = int(cfg.ssm.proj_factor * cfg.d_model)
    H = cfg.n_heads
    return d_in, H, d_in // H


def mlstm_param_shapes(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    d_in, H, dh = xlstm_dims(cfg)
    return {
        "up_proj": (D, 2 * d_in),
        "wq": (d_in, d_in),
        "wk": (d_in, d_in),
        "wv": (d_in, d_in),
        "wif": (d_in, 2 * H),        # input & forget gate pre-activations
        "o_norm": (d_in,),
        "down_proj": (d_in, D),
    }


def slstm_param_shapes(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    return {
        "wx": (D, 4 * D),            # i, f, z, o from input
        "rh": (D, 4 * D),            # recurrent
        "b": (4 * D,),
        "ff1": (D, int(cfg.ssm.proj_factor * D)),
        "ff2": (int(cfg.ssm.proj_factor * D), D),
    }


def _init_from_shapes(key, shapes, dtype):
    ks = jax.random.split(key, len(shapes))
    out = {}
    for (name, shape), k in zip(sorted(shapes.items()), ks):
        if name.endswith("norm"):
            out[name] = jnp.ones(shape, dtype)
        elif name == "b":
            out[name] = jnp.zeros(shape, dtype)
        else:
            out[name] = dense_init(k, shape, in_dim=shape[0], dtype=dtype)
    return out


def init_mlstm_params(key, cfg, dtype=jnp.float32):
    return _init_from_shapes(key, mlstm_param_shapes(cfg), dtype)


def init_slstm_params(key, cfg, dtype=jnp.float32):
    return _init_from_shapes(key, slstm_param_shapes(cfg), dtype)


def mlstm_forward(w: dict, x, cfg: ModelConfig, state: MLSTMState | None = None):
    """Chunkwise-parallel mLSTM. x: (B, S, D) -> (y, state).

    Exponential-gated linear attention with matrix memory (xLSTM eq. 19-27),
    evaluated chunk-by-chunk: intra-chunk = masked attention in the chunk,
    inter-chunk = decayed matrix-memory carry.
    """
    B, S, D = x.shape
    d_in, H, dh = xlstm_dims(cfg)
    Q = min(cfg.ssm.chunk, S)
    while S % Q:
        Q -= 1
    nchunk = S // Q

    up = x @ w["up_proj"]
    u, z = jnp.split(up, 2, axis=-1)                          # (B,S,d_in)
    q = (u @ w["wq"]).reshape(B, S, H, dh) / np.sqrt(dh)
    k = (u @ w["wk"]).reshape(B, S, H, dh) / np.sqrt(dh)
    v = (u @ w["wv"]).reshape(B, S, H, dh)
    gates = (u @ w["wif"]).astype(jnp.float32)                # (B,S,2H)
    logi = gates[..., :H]                                     # input gate (log)
    logf = jax.nn.log_sigmoid(gates[..., H:])                 # forget gate (log)

    if state is None:
        C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, H, dh), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    def chunk_step(carry, xs):
        C, n, mprev, = carry
        qc, kc, vc, lic, lfc = xs                             # (B,Q,H,*)
        lf_cum = jnp.cumsum(lfc, axis=1)                      # (B,Q,H)
        # stabilizer per position: m_t = max(m_prev + lf_cum, max_s<=t(...))
        a = lf_cum[:, :, None] - lf_cum[:, None, :] + lic[:, None, :]
        qpos = jnp.arange(Q)
        causal = qpos[:, None] >= qpos[None, :]
        a = jnp.where(causal[None, :, :, None], a, -1e30)     # (B,Q,Q,H)
        inter_m = mprev[:, None] + lf_cum                     # (B,Q,H)
        intra_m = a.max(axis=2)
        m_t = jnp.maximum(inter_m, intra_m)                   # (B,Q,H)
        # intra-chunk weights
        wgt = jnp.exp(a - m_t[:, :, None])                    # (B,Q,Q,H)
        s = jnp.einsum("bqhd,bshd->bqsh", qc.astype(jnp.float32),
                       kc.astype(jnp.float32))
        intra_num = jnp.einsum("bqsh,bqsh,bshd->bqhd", s, wgt,
                               vc.astype(jnp.float32))
        intra_den = jnp.einsum("bqsh,bqsh->bqh", s, wgt)
        # inter-chunk: decayed memory read
        decay = jnp.exp(inter_m - m_t)                        # (B,Q,H)
        inter_num = jnp.einsum("bqhd,bhed->bqhe", qc.astype(jnp.float32), C)
        inter_den = jnp.einsum("bqhd,bhd->bqh", qc.astype(jnp.float32), n)
        num = intra_num + inter_num * decay[..., None]
        den = jnp.abs(intra_den + inter_den * decay)
        y = num / jnp.maximum(den, jnp.exp(-m_t))[..., None]
        # memory update to end of chunk
        m_end = m_t[:, -1]
        wk = jnp.exp(lf_cum[:, -1:, :] - lf_cum + lic - m_end[:, None])
        C_new = (C * jnp.exp(mprev + lf_cum[:, -1] - m_end)[..., None, None]
                 + jnp.einsum("bsh,bshd,bshe->bhde", wk,
                              vc.astype(jnp.float32), kc.astype(jnp.float32)))
        n_new = (n * jnp.exp(mprev + lf_cum[:, -1] - m_end)[..., None]
                 + jnp.einsum("bsh,bshd->bhd", wk, kc.astype(jnp.float32)))
        return (C_new, n_new, m_end), y

    qc = jnp.moveaxis(q.reshape(B, nchunk, Q, H, dh), 1, 0)
    kc = jnp.moveaxis(k.reshape(B, nchunk, Q, H, dh), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nchunk, Q, H, dh), 1, 0)
    lic = jnp.moveaxis(logi.reshape(B, nchunk, Q, H), 1, 0)
    lfc = jnp.moveaxis(logf.reshape(B, nchunk, Q, H), 1, 0)
    (C, n, m), ys = jax.lax.scan(chunk_step, (C0, n0, m0),
                                 (qc, kc, vc, lic, lfc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, d_in).astype(x.dtype)
    y = y * w["o_norm"]
    y = y * jax.nn.silu(z)
    return y @ w["down_proj"], MLSTMState(C, n, m)


def slstm_forward(w: dict, x, cfg: ModelConfig, state: SLSTMState | None = None):
    """Sequential sLSTM with exponential gating + small FFN. x: (B,S,D)."""
    B, S, D = x.shape
    if state is None:
        z0 = jnp.zeros((B, D), jnp.float32)
        state = SLSTMState(z0, z0, z0, jnp.full((B, D), -1e30, jnp.float32))

    wx = (x @ w["wx"]).astype(jnp.float32)                    # (B,S,4D)

    def step(st, xt):
        c, n, h, m = st
        pre = xt + h @ w["rh"].astype(jnp.float32) + w["b"].astype(jnp.float32)
        i_, f_, z_, o_ = jnp.split(pre, 4, axis=-1)
        logf = jax.nn.log_sigmoid(f_)
        m_new = jnp.maximum(logf + m, i_)
        i_g = jnp.exp(i_ - m_new)
        f_g = jnp.exp(logf + m - m_new)
        c_new = f_g * c + i_g * jnp.tanh(z_)
        n_new = f_g * n + i_g
        h_new = jax.nn.sigmoid(o_) * c_new / jnp.maximum(n_new, 1e-6)
        return SLSTMState(c_new, n_new, h_new, m_new), h_new

    st, hs = jax.lax.scan(step, state, jnp.moveaxis(wx, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)                # (B,S,D)
    y = jax.nn.gelu(h @ w["ff1"]) @ w["ff2"]
    return y, st
