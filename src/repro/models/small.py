"""The paper's own experiment models: 3-conv CNN, 4-hidden MLP, logreg.

These are classification models over image-shaped inputs — the workloads of
the paper's Figures 8-12. They implement the same Model-ish API surface
(init / loss / predict) and are pytree-generic so every FL strategy works on
them unchanged (RQ2: model agnosticism).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init
from repro.sharding.axes import AxisCtx

CIFAR_SHAPE = (32, 32, 3)
MNIST_SHAPE = (28, 28, 1)


def _conv(x, w, b):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


@dataclasses.dataclass(frozen=True)
class SmallModel:
    cfg: ModelConfig
    kind: str                     # cnn | mlp | logreg

    def init(self, key, dtype=jnp.float32):
        ks = jax.random.split(key, 12)
        C = self.cfg.vocab_size   # num classes
        if self.kind == "cnn":
            ch = self.cfg.d_model                        # 64
            p = {
                "c1": dense_init(ks[0], (3, 3, 3, ch // 2), 27, dtype),
                "b1": jnp.zeros((ch // 2,), dtype),
                "c2": dense_init(ks[1], (3, 3, ch // 2, ch), 9 * ch // 2, dtype),
                "b2": jnp.zeros((ch,), dtype),
                "c3": dense_init(ks[2], (3, 3, ch, ch), 9 * ch, dtype),
                "b3": jnp.zeros((ch,), dtype),
                "fc": dense_init(ks[3], (4 * 4 * ch, self.cfg.d_ff), 4 * 4 * ch, dtype),
                "fb": jnp.zeros((self.cfg.d_ff,), dtype),
                "out": dense_init(ks[4], (self.cfg.d_ff, C), self.cfg.d_ff, dtype),
                "ob": jnp.zeros((C,), dtype),
            }
        elif self.kind == "mlp":
            d_in = int(np.prod(CIFAR_SHAPE))
            h = self.cfg.d_model
            p = {"w0": dense_init(ks[0], (d_in, h), d_in, dtype),
                 "b0": jnp.zeros((h,), dtype)}
            for i in range(1, self.cfg.n_layers):
                p[f"w{i}"] = dense_init(ks[i], (h, h), h, dtype)
                p[f"b{i}"] = jnp.zeros((h,), dtype)
            p["out"] = dense_init(ks[10], (h, C), h, dtype)
            p["ob"] = jnp.zeros((C,), dtype)
        else:  # logreg
            d_in = self.cfg.d_model                      # 784
            p = {"w": jnp.zeros((d_in, C), dtype), "b": jnp.zeros((C,), dtype)}
        return p

    def logits(self, params, x):
        if self.kind == "cnn":
            h = x
            for i, name in enumerate(["c1", "c2", "c3"]):
                h = _conv(h, params[name], params[f"b{i + 1}"])
                h = jax.nn.relu(h)
                h = jax.lax.reduce_window(
                    h, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1),
                    "VALID")
            h = h.reshape(h.shape[0], -1)
            h = jax.nn.relu(h @ params["fc"] + params["fb"])
            return h @ params["out"] + params["ob"]
        if self.kind == "mlp":
            h = x.reshape(x.shape[0], -1)
            for i in range(self.cfg.n_layers):
                h = jax.nn.relu(h @ params[f"w{i}"] + params[f"b{i}"])
            return h @ params["out"] + params["ob"]
        h = x.reshape(x.shape[0], -1)
        return h @ params["w"] + params["b"]

    def loss(self, ctx: AxisCtx, params, batch, gather_fn=lambda b: b):
        lg = self.logits(params, batch["x"]).astype(jnp.float32)
        lp = jax.nn.log_softmax(lg, -1)
        nll = -jnp.take_along_axis(lp, batch["y"][:, None], 1).mean()
        nll = ctx.pmean(nll, ctx.data_axes)
        return nll, {"loss": nll}

    def accuracy(self, params, batch):
        lg = self.logits(params, batch["x"])
        return (jnp.argmax(lg, -1) == batch["y"]).mean()

    def shapes(self):
        p = self.init(jax.random.PRNGKey(0))
        return jax.tree.map(lambda t: t.shape, p)


def build_small(cfg: ModelConfig) -> SmallModel:
    kind = {"flsim-cnn": "cnn", "flsim-mlp": "mlp",
            "flsim-logreg": "logreg"}[cfg.name]
    return SmallModel(cfg, kind)


def count_small_params(cfg: ModelConfig) -> int:
    m = build_small(cfg)
    p = m.init(jax.random.PRNGKey(0))
    return sum(int(np.prod(t.shape)) for t in jax.tree.leaves(p))


def input_shape(cfg: ModelConfig):
    return MNIST_SHAPE if cfg.name == "flsim-logreg" else CIFAR_SHAPE
