"""Mixture-of-Experts with TPU-native expert parallelism.

Layout (see DESIGN.md): the expert dim is sharded over the ``data`` axis
(E_row = E / R experts per data row, resident — never gathered), the expert
FFN dim over ``model`` (F_loc = F / M). One MoE layer's communication:

  dispatch:  capacity buckets -> all_to_all(data) -> all_gather(model, tokens)
  compute:   grouped matmuls on (E_row, C_tot, *) buckets
  combine:   psum_scatter(model, tokens) -> all_to_all(data) -> weighted gather

The psum_scatter chunk of model-chip m is exactly the token block gathered
FROM m, so the reverse path lands every result back in its source slot with
no metadata exchange — dropped tokens ride through as zero-padded slots.

For decode the activations are already replicated over ``model`` (TP phase),
so the token all-gather is skipped and the combine is a plain psum.

With a no-axis ``AxisCtx`` this reduces to single-device capacity-bucket MoE
(the oracle for tests, compared against a dense masked reference).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init
from repro.sharding.axes import AxisCtx


class MoEAux(NamedTuple):
    load_balance: jnp.ndarray
    z_loss: jnp.ndarray
    drop_fraction: jnp.ndarray


def moe_param_shapes(cfg: ModelConfig) -> dict:
    m = cfg.moe
    D, E, F = cfg.d_model, m.n_experts, m.expert_d_ff
    if m.ep_mode == "subgrid":
        # (expert, f-slice) packed on one leading dim so a single named-axis
        # product (data x model) shards it; parameter count is unchanged.
        fs = m.f_sub
        return {
            "router": (D, E),
            "w1": (E * fs, D, F // fs),
            "w3": (E * fs, D, F // fs),
            "w2": (E * fs, F // fs, D),
        }
    return {
        "router": (D, E),
        "w1": (E, D, F),
        "w3": (E, D, F),
        "w2": (E, F, D),
    }


def init_moe_params(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    shapes = moe_param_shapes(cfg)
    ks = jax.random.split(key, len(shapes))
    out = {}
    for (name, shape), k in zip(sorted(shapes.items()), ks):
        in_dim = shape[-2] if len(shape) == 3 else shape[0]
        out[name] = dense_init(k, shape, in_dim=in_dim, dtype=dtype)
    return out


def capacity(n_tokens: int, top_k: int, n_experts: int, cf: float) -> int:
    c = int(math.ceil(cf * n_tokens * top_k / n_experts))
    return max(8, (c + 7) // 8 * 8)


def moe_ffn(ctx: AxisCtx, w: dict, x, cfg: ModelConfig,
            *, tokens_replicated: bool = False):
    """x: (B, T_loc, D) local tokens. w: router full; w1/w3/w2 LOCAL shards.

    ep_mode="model": expert shards (E/M, D, F) over the model axis.
    ep_mode="grid":  expert shards (E/R, D, F/M) over data x model.
    Returns (out, MoEAux)."""
    m = cfg.moe
    B, T_loc, D = x.shape
    E, K = m.n_experts, m.top_k
    ep_axis = ctx.model if m.ep_mode == "model" else ctx.data
    R = ctx.size(ep_axis)
    E_row = E // R
    xf = x.reshape(B * T_loc, D)
    T = xf.shape[0]
    if m.ep_mode == "subgrid":
        return _moe_subgrid(ctx, w, xf, cfg, B, T_loc,
                            tokens_replicated=tokens_replicated)

    # --- routing (f32) -------------------------------------------------
    logits = (xf.astype(jnp.float32) @ w["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eids = jax.lax.top_k(probs, K)                    # (T, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # aux losses (GShard-style)
    me = probs.mean(0)                                       # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[eids.reshape(-1)].add(1.0) / (T * K)
    load_balance = E * jnp.sum(me * ce) * m.load_balance_loss
    z_loss = jnp.mean(jax.nn.logsumexp(logits, -1) ** 2) * m.router_z_loss

    # --- capacity bucketing --------------------------------------------
    C = capacity(T, K, E, m.capacity_factor)
    flat_e = eids.reshape(-1)                                # (T*K,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) - 1)
    pos = jnp.take_along_axis(pos, flat_e[:, None], 1)[:, 0]  # slot in expert
    keep = pos < C
    drop_fraction = 1.0 - keep.mean()
    slot = jnp.where(keep, flat_e * (C + 1) + pos, flat_e * (C + 1) + C)
    buf = jnp.zeros((E * (C + 1), D), x.dtype)
    buf = buf.at[slot].set(jnp.repeat(xf, K, axis=0))
    buf = buf.reshape(E, C + 1, D)[:, :C]                    # (E, C, D)

    # --- dispatch collectives ------------------------------------------
    if ep_axis is not None:
        b = buf.reshape(R, E_row, C, D)
        b = ctx.all_to_all(b, ep_axis, split_axis=0, concat_axis=0)
        buckets = jnp.moveaxis(b, 0, 1).reshape(E_row, R * C, D)
    else:
        buckets = buf.reshape(E_row, R * C, D)
    grid_mode = m.ep_mode == "grid" and ctx.model is not None

    def expert_ffn(toks):
        g = jnp.einsum("ecd,edf->ecf", toks, w["w1"])
        u = jnp.einsum("ecd,edf->ecf", toks, w["w3"])
        h = jax.nn.silu(g) * u
        return jnp.einsum("ecf,efd->ecd", h, w["w2"])

    if grid_mode and not tokens_replicated:
        # Ring-chunked expert compute: the expert FFN dim is model-sharded,
        # so every token needs every F-shard. Instead of all-gathering the
        # (E_row, M*R*C, D) token buckets (token replication x M — hundreds
        # of GiB for jamba), each chip's chunk CIRCULATES around the model
        # ring; each hop applies the local F-slice and accumulates into the
        # traveling output. After M hops the chunk is home, fully combined.
        # Same total bytes as AG+reduce-scatter, O(1/M) live memory, and the
        # per-hop ppermute overlaps with the matmul.
        #
        # REPRO_QUANT_RING=1 (EXPERIMENTS.md §Perf, jamba): circulate int8
        # payloads with per-token scales — visit is quantized ONCE (no
        # re-quantization error); the traveling accumulator is requantized
        # each hop (error ~0.4%/hop of row max, flag-gated).
        import os
        M = ctx.size(ctx.model)
        perm = [(i, (i + 1) % M) for i in range(M)]
        quant_ring = os.environ.get("REPRO_QUANT_RING") == "1"

        def q8(t):
            amax = jnp.max(jnp.abs(t.astype(jnp.float32)), -1, keepdims=True)
            sc = jnp.where(amax > 0, amax / 127.0, 1.0)
            q = jnp.clip(jnp.round(t.astype(jnp.float32) / sc),
                         -127, 127).astype(jnp.int8)
            return q, sc.astype(jnp.float32)

        def dq(q, sc, dt):
            return (q.astype(jnp.float32) * sc).astype(dt)

        if quant_ring:
            vq, vs = q8(buckets)

            def hop(carry, _):
                vq_, vs_, aq, asc = carry
                visit = dq(vq_, vs_, buckets.dtype)
                acc = dq(aq, asc, jnp.float32) + expert_ffn(visit) \
                    .astype(jnp.float32)
                aq2, as2 = q8(acc)
                return (ctx.ppermute(vq_, ctx.model, perm),
                        ctx.ppermute(vs_, ctx.model, perm),
                        ctx.ppermute(aq2, ctx.model, perm),
                        ctx.ppermute(as2, ctx.model, perm)), None

            aq0, as0 = q8(jnp.zeros_like(buckets))
            (_, _, aq, asc), _ = jax.lax.scan(hop, (vq, vs, aq0, as0),
                                              None, length=M)
            part = dq(aq, asc, buckets.dtype)
        else:
            def hop(carry, _):
                visit, acc = carry
                acc = acc + expert_ffn(visit)
                visit = ctx.ppermute(visit, ctx.model, perm)
                acc = ctx.ppermute(acc, ctx.model, perm)
                return (visit, acc), None

            acc0 = jnp.zeros_like(buckets)
            (_, part), _ = jax.lax.scan(hop, (buckets, acc0), None, length=M)
    else:
        part = expert_ffn(buckets)
        if grid_mode:                     # decode: tokens replicated over M
            part = ctx.psum(part, ctx.model)

    if ep_axis is not None:
        p = jnp.moveaxis(part.reshape(E_row, R, C, D), 1, 0)
        p = ctx.all_to_all(p, ep_axis, split_axis=0, concat_axis=0)
        out_buf = p.reshape(E, C, D)
    else:
        out_buf = part.reshape(E, C, D)

    # --- weighted un-permute --------------------------------------------
    flat_idx = jnp.minimum(flat_e * C + pos, E * C - 1)
    tok = out_buf.reshape(E * C, D)[flat_idx]                # (T*K, D)
    tok = tok * (keep * gates.reshape(-1)).astype(tok.dtype)[:, None]
    out = tok.reshape(T, K, D).sum(1).reshape(B, T_loc, D)
    return out, MoEAux(load_balance, z_loss, drop_fraction)


def _moe_subgrid(ctx: AxisCtx, w: dict, xf, cfg: ModelConfig, B, T_loc,
                 *, tokens_replicated: bool = False):
    """Sub-grid EP (the arctic hillclimb; EXPERIMENTS.md §Perf).

    Weights are stored (E*f_sub, D, F/f_sub) sharded over the flattened
    (data x model) grid: chip (r, m) holds FFN slice (m % f_sub) of expert
    (r * M/f_sub + m // f_sub). Communication per layer:

      data-a2a (row dispatch)  ->  model-a2a with f_sub-fold duplication
      -> local grouped matmul  ->  butterfly XOR partial-sum (log2 f_sub
      ppermute+add steps)      ->  reverse a2a's.

    vs the ring: bytes drop from 2*(M-1)*bucket to ~(2 + f_sub)*bucket —
    ~6.5x for arctic (f_sub=2) — because tokens only visit the f_sub chips
    that actually hold their expert, not all M F-shards.
    """
    m = cfg.moe
    E, K, fs = m.n_experts, m.top_k, m.f_sub
    D = xf.shape[-1]
    T = xf.shape[0]
    R = ctx.size(ctx.data)
    M = ctx.size(ctx.model)
    E_row = E // R
    if ctx.model is not None:
        assert E_row * fs == M, \
            f"subgrid needs E/data*f_sub == model ({E_row}*{fs} != {M})"

    # --- routing + capacity bucketing (same as the generic path) --------
    logits = xf.astype(jnp.float32) @ w["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eids = jax.lax.top_k(probs, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    me = probs.mean(0)
    ce = jnp.zeros((E,), jnp.float32).at[eids.reshape(-1)].add(1.0) / (T * K)
    load_balance = E * jnp.sum(me * ce) * m.load_balance_loss
    z_loss = jnp.mean(jax.nn.logsumexp(logits, -1) ** 2) * m.router_z_loss
    C = capacity(T, K, E, m.capacity_factor)
    flat_e = eids.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) - 1)
    pos = jnp.take_along_axis(pos, flat_e[:, None], 1)[:, 0]
    keep = pos < C
    drop_fraction = 1.0 - keep.mean()
    slot = jnp.where(keep, flat_e * (C + 1) + pos, flat_e * (C + 1) + C)
    buf = jnp.zeros((E * (C + 1), D), xf.dtype)
    buf = buf.at[slot].set(jnp.repeat(xf, K, axis=0))
    buf = buf.reshape(E, C + 1, D)[:, :C]                     # (E, C, D)

    if ctx.model is None:
        # single-device oracle: reassemble (E, D, F) from the packed slices
        def full(t, transpose=False):
            if transpose:   # w2 (E*fs, F/fs, D) -> (E, F, D)
                return t.reshape(E, fs, -1, D).reshape(E, -1, D)
            return jnp.moveaxis(t.reshape(E, fs, D, -1), 1, 2) \
                .reshape(E, D, -1)
        g = jnp.einsum("ecd,edf->ecf", buf, full(w["w1"]))
        u = jnp.einsum("ecd,edf->ecf", buf, full(w["w3"]))
        part = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                          full(w["w2"], transpose=True))
        out_buf = part
    else:
        # dispatch to expert rows
        b = buf.reshape(R, E_row, C, D)
        b = ctx.all_to_all(b, ctx.data, split_axis=0, concat_axis=0)
        buckets = jnp.moveaxis(b, 0, 1).reshape(E_row, R * C, D)
        if tokens_replicated:
            # decode: buckets identical on all model chips; each chip runs
            # its (expert, slice), psum combines slices AND fills slots.
            idx = ctx.index(ctx.model)
            mine = buckets[idx // fs]                           # (R*C, D)
            g = mine @ w["w1"][0]
            u = mine @ w["w3"][0]
            part_own = (jax.nn.silu(g) * u) @ w["w2"][0]        # (R*C, D)
            part = jnp.zeros_like(buckets)
            part = jax.lax.dynamic_update_index_in_dim(
                part, part_own, idx // fs, axis=0)
            part = ctx.psum(part, ctx.model)
        else:
            # duplicate each expert's bucket to its f_sub slice-holders
            visit = jnp.repeat(buckets, fs, axis=0)             # (M, R*C, D)
            visit = ctx.all_to_all(visit, ctx.model, split_axis=0,
                                   concat_axis=0)               # (M, R*C, D)
            toks = visit.reshape(M * R * C, D)
            g = toks @ w["w1"][0]                               # (MRC, F/fs)
            u = toks @ w["w3"][0]
            partial = (jax.nn.silu(g) * u) @ w["w2"][0]         # (MRC, D)
            # butterfly partial-sum within each f_sub-aligned group
            k = 1
            while k < fs:
                perm = [(i, i ^ k) for i in range(M)]
                partial = partial + ctx.ppermute(partial, ctx.model, perm)
                k *= 2
            # reverse a2a; halves carry identical sums -> take every fs-th
            back = ctx.all_to_all(partial.reshape(M, R * C, D), ctx.model,
                                  split_axis=0, concat_axis=0)
            part = back[::fs]                                   # (E_row,R*C,D)
        p = jnp.moveaxis(part.reshape(E_row, R, C, D), 1, 0)
        p = ctx.all_to_all(p, ctx.data, split_axis=0, concat_axis=0)
        out_buf = p.reshape(E, C, D)

    flat_idx = jnp.minimum(flat_e * C + pos, E * C - 1)
    tok = out_buf.reshape(E * C, D)[flat_idx]
    tok = tok * (keep * gates.reshape(-1)).astype(tok.dtype)[:, None]
    out = tok.reshape(T, K, D).sum(1).reshape(B, T_loc, D)
    return out, MoEAux(load_balance, z_loss, drop_fraction)


def moe_ffn_dense_ref(w_full: dict, x, cfg: ModelConfig):
    """Dense masked reference (no capacity drops): every token runs its top-k
    experts exactly. O(E) compute — tests only."""
    m = cfg.moe
    B, T, D = x.shape
    xf = x.reshape(B * T, D)
    logits = xf.astype(jnp.float32) @ w_full["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gates, eids = jax.lax.top_k(probs, m.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    g = jnp.einsum("td,edf->tef", xf, w_full["w1"])
    u = jnp.einsum("td,edf->tef", xf, w_full["w3"])
    h = jax.nn.silu(g) * u
    y = jnp.einsum("tef,efd->ted", h, w_full["w2"])          # (T, E, D)
    mask = jnp.zeros((xf.shape[0], m.n_experts), jnp.float32)
    mask = mask.at[jnp.arange(xf.shape[0])[:, None], eids].add(gates)
    out = jnp.einsum("te,ted->td", mask, y)
    return out.reshape(B, T, D).astype(x.dtype)
