"""Model registry + parameter accounting."""
from __future__ import annotations

import math

import jax

from repro.configs.base import ModelConfig, get_config
from repro.models import transformer


def build(name_or_cfg) -> "transformer.Model":
    cfg = (name_or_cfg if isinstance(name_or_cfg, ModelConfig)
           else get_config(name_or_cfg))
    if cfg.family == "small":
        from repro.models import small
        return small.build_small(cfg)
    return transformer.build_model(cfg)


def _tree_numel(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, tuple))
    return sum(math.prod(sh) for sh in leaves)


def count_params(cfg: ModelConfig, padded: bool = False,
                 active_only: bool = False) -> int:
    """Parameter count from the logical shape tree.

    padded=False discounts the vocab padding (reports the paper-faithful N);
    active_only replaces each MoE layer's expert count with top_k (the 6*N_active*D
    roofline numerator for MoE archs).
    """
    if cfg.family == "small":
        from repro.models import small
        return small.count_small_params(cfg)
    shapes = transformer.param_shapes(cfg)
    total = _tree_numel(shapes)
    if not padded:
        dv = (cfg.padded_vocab - cfg.vocab_size) * cfg.d_model
        total -= dv  # embed
        if not cfg.tie_embeddings:
            total -= dv  # lm_head
    if active_only and cfg.moe is not None:
        m = cfg.moe
        if cfg.family == "hybrid":
            n_moe_layers = (cfg.n_layers // cfg.hybrid.period) * \
                           (cfg.hybrid.period // m.moe_every)
        else:
            n_moe_layers = cfg.n_layers // m.moe_every
        per_expert = 3 * cfg.d_model * m.expert_d_ff
        total -= n_moe_layers * (m.n_experts - m.top_k) * per_expert
    return int(total)
