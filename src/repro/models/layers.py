"""Shared neural-net layers: norms, rotary embeddings, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def rms_norm(x, w, eps: float = 1e-6):
    return ops.rmsnorm(x, w, eps=eps)


def layer_norm(x, w, b, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w + b).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (B, S, H, D) with D even; positions: (S,) or (B, S)."""
    D = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(D, theta), jnp.float32)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freqs[None, :]  # (S, D/2)
        ang = ang[None, :, None, :]
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs
        ang = ang[:, :, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def dense_init(key, shape, in_dim: int, dtype=jnp.float32, scale: float = 1.0):
    std = scale / np.sqrt(in_dim)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)
