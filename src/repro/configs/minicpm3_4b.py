"""minicpm3-4b — dense, MLA. [hf:openbmb/MiniCPM3-4B; hf]"""
from repro.configs.base import ModelConfig, MLAConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    attn_type="mla",
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                  qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
    tie_embeddings=True,
    notes="MLA latent KV cache (kv_lora 256 + rope 32)",
    source="hf:openbmb/MiniCPM3-4B",
)
