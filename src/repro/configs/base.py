"""Configuration dataclasses for models, shapes, meshes and FL jobs.

Every assigned architecture gets a module ``src/repro/configs/<id>.py`` exposing
``CONFIG: ModelConfig``. ``registry.get(name)`` resolves them. The paper's own
models (3-conv CNN, 4-hidden MLP, logistic regression) live in ``flsim_small.py``.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek/MiniCPM3 style)."""
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    # Layers with ``layer_idx % moe_every == moe_offset`` use MoE (rest dense MLP).
    moe_every: int = 1
    moe_offset: int = 0
    # Arctic: a dense FFN residual branch runs in parallel with the MoE branch.
    dense_residual_d_ff: int = 0
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2
    # Expert-parallel layout: "model" = experts sharded over the model axis,
    # full expert FFN per chip (small experts, e.g. qwen3); "grid" = experts
    # over data x expert-FFN over model with ring-chunked compute (experts too
    # big for one chip's slice budget: jamba); "subgrid" = experts x f_sub
    # FFN-slices packed onto the flattened (data x model) grid with butterfly
    # partial-sums (arctic post-hillclimb; needs E*f_sub == n_chips).
    # See DESIGN.md, models/moe.py and EXPERIMENTS.md §Perf.
    ep_mode: str = "model"
    f_sub: int = 1


@dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba"           # "mamba" | "xlstm"
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0              # 0 -> d_model // 16
    chunk: int = 256              # chunked-scan block size
    # xlstm: one sLSTM block per ``slstm_every`` blocks, rest mLSTM.
    slstm_every: int = 4
    proj_factor: float = 2.0      # xlstm up-projection


@dataclass(frozen=True)
class HybridConfig:
    """Jamba-style periodic layout."""
    period: int = 8
    attn_index: int = 4           # which layer inside the period is attention


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | encdec | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_type: str = "gqa"        # gqa | mla
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # encoder-decoder only
    n_enc_layers: int = 0
    dec_len_ratio: int = 8        # decoder length = seq_len // ratio
    # modality frontend is a stub; "token" (ids) or "frames" (precomputed embeds)
    input_kind: str = "token"
    notes: str = ""
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so it shards over any mesh axis."""
        v, m = self.vocab_size, 256
        return (v + m - 1) // m * m

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    # Parameter counting (used by tests and MODEL_FLOPS=6ND roofline term)
    # ------------------------------------------------------------------
    def param_count(self, padded: bool = False) -> int:
        from repro.models.model_zoo import count_params
        return count_params(self, padded=padded)

    def active_param_count(self, padded: bool = False) -> int:
        from repro.models.model_zoo import count_params
        return count_params(self, padded=padded, active_only=True)


# ---------------------------------------------------------------------------
# Input shapes (assigned set)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}

# Archs with sub-quadratic token mixing run long_500k; pure full-attention archs
# skip it (assignment rule; see DESIGN.md §Arch-applicability).
SUBQUADRATIC = ("xlstm-125m", "jamba-1.5-large-398b")


def shapes_for(arch: str) -> Sequence[str]:
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in SUBQUADRATIC:
        names.append("long_500k")
    return tuple(names)


# ---------------------------------------------------------------------------
# FL job configuration (mirrors paper Fig. 2)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FLConfig:
    strategy: str = "fedavg"          # core strategy name
    topology: str = "client_server"   # client_server | hierarchical | decentralized
    placement: str = "auto"           # spatial | temporal | auto
    # rounds fused into one compiled launch (lax.scan); host I/O (checkpoint,
    # ledger, eval, logging) happens only at chunk boundaries. 1 == per-round
    # host loop; chunked and unchunked runs are bitwise-identical by contract.
    rounds_per_launch: int = 1
    # execution mode: "sync" = round-synchronous (the paper's Alg. 1);
    # "async" = event-driven over a virtual clock (core/async_rounds.py).
    # Async "rounds" are logging/chunking units of events_per_round server
    # events (= async_buffer for FedBuff, n_clients for FedAsync).
    mode: str = "sync"
    async_buffer: int = 0             # <=1 -> FedAsync; K>1 -> FedBuff(K)
    staleness_exponent: float = 0.0   # alpha_s = (1+staleness)^-exponent
    max_staleness: int = 8            # older arrivals are discarded
    async_concurrency: int = 0        # clients in flight (0 -> all)
    n_clients: int = 16               # virtual clients (cohort per round)
    cohort: int = 0                   # 0 -> all clients each round
    # Ragged client plane: > 0 pads the per-round cohort to this many slots
    # and zero-weights the tail, so the compiled program sees ``max_cohort``
    # slots instead of ``n_clients`` clients — ``n_clients``/``cohort`` drop
    # out of the program signature (core/plan.py) and become sweepable
    # host-side slab-plan values (core/sweeps.py). Must be >= the per-round
    # cohort (``cohort`` or, with cohort=0, ``n_clients``). 0 keeps the
    # dense all-clients-resident path.
    max_cohort: int = 0
    # Streaming data plane (ragged mode only): stage only the sampled
    # cohorts' shards per chunk from host memory, double-buffered so the
    # host->device copy of chunk k+1 overlaps chunk k's compiled scan.
    # Breaks the "all clients resident in HBM" ceiling; bitwise identical
    # to resident slab staging (data/pipeline.py stagers).
    streaming: bool = False
    local_epochs: int = 1
    local_steps: int = 1              # local optimizer steps per epoch
    batch_size: int = 32              # per-client local batch (device gather)
    client_lr: float = 0.1
    client_optimizer: str = "sgd"     # sgd | sgdm | adam
    client_momentum: float = 0.0
    server_lr: float = 1.0
    server_optimizer: str = "none"    # none | momentum | adam | yogi
    server_momentum: float = 0.9
    # strategy extras
    prox_mu: float = 0.0              # FedProx
    dp_clip: float = 0.0              # DP-FedAvg
    dp_noise: float = 0.0
    moon_mu: float = 0.0              # MOON contrastive weight
    moon_tau: float = 0.5
    compression: str = "none"         # none | int8 | topk
    topk_ratio: float = 0.01
    error_feedback: bool = True
    # multi-worker consensus
    n_workers: int = 1
    consensus: str = "majority_digest"
    byzantine_workers: int = 0
    # decentralized
    gossip_steps: int = 1
    # data
    partition: str = "dirichlet"      # dirichlet | iid | shards
    dirichlet_alpha: float = 0.5
    seed: int = 0
    deterministic: bool = True
    # runtime / fault-tolerance
    straggler_overprovision: float = 1.0
    drop_tolerance: float = 0.0       # fraction of clients allowed to drop per round
    checkpoint_every: int = 0
    blockchain: str = "none"          # none | hashchain
    # async-mode ledger digest cadence: every this-many server events the
    # chunk loop appends a consensus digest block (0 = off). Evaluated at
    # chunk boundaries; recorded as a "digest" span + counter.
    digest_every_events: int = 0
    rounds: int = 10


# FLConfig scalars a campaign (job `sweep:` section, core/sweeps.py) may
# thread into the compiled round/event programs as *traced* per-trajectory
# values. Everything here must be consumed purely arithmetically inside the
# traced path — no Python control flow on it — so one compiled program
# serves any value (rounds.bind_hyper rebinds them at trace time; "seed"
# additionally steers the data plane and the in-program cohort draw).
SWEEPABLE_SCALARS = ("seed", "client_lr", "server_lr", "server_momentum",
                     "prox_mu", "moon_mu", "moon_tau", "dp_clip", "dp_noise")

# FLConfig fields a campaign may sweep *categorically*: each value changes
# the traced computation itself (strategy kind, topology reduction plan,
# placement, sync-vs-async event loop, FedAsync-vs-FedBuff(K)), so these
# axes cannot ride the scalar-plane vmap. The planner (core/plan.py)
# buckets trajectories by program signature and vmaps within each bucket
# instead — a heterogeneous grid compiles one program per bucket, not one
# per trajectory.
SWEEPABLE_CATEGORICAL = ("strategy", "topology", "placement", "mode",
                         "async_buffer", "compression")


@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False
    data: int = 16
    model: int = 16
    pods: int = 2
    # Device-parallel campaigns: the leading sweep-lane axis. Campaign lanes
    # are embarrassingly parallel, so `lanes > 1` shards the (S,) sweep dim
    # of every campaign plane over that many devices (launch/mesh.lane_mesh;
    # runtime/campaign.py pads S up to a multiple with dead lanes). 1 keeps
    # the single-device vmap.
    lanes: int = 1

    @property
    def shape(self):
        base = ((self.pods, self.data, self.model) if self.multi_pod
                else (self.data, self.model))
        return (self.lanes,) + base if self.lanes > 1 else base

    @property
    def axes(self):
        base = (("pod", "data", "model") if self.multi_pod
                else ("data", "model"))
        return ("lanes",) + base if self.lanes > 1 else base

    @property
    def n_chips(self) -> int:
        n = self.data * self.model
        if self.multi_pod:
            n *= self.pods
        return n * self.lanes if self.lanes > 1 else n


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCHS = (
    "minicpm3-4b",
    "qwen2.5-32b",
    "yi-34b",
    "qwen1.5-32b",
    "whisper-base",
    "qwen3-moe-30b-a3b",
    "arctic-480b",
    "chameleon-34b",
    "xlstm-125m",
    "jamba-1.5-large-398b",
)

_SMALL = ("flsim-cnn", "flsim-mlp", "flsim-logreg")

_MODULE_FOR = {a: a.replace("-", "_").replace(".", "_") for a in ARCHS}
_MODULE_FOR.update({a: "flsim_small" for a in _SMALL})


def get_config(name: str) -> ModelConfig:
    if name not in _MODULE_FOR:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULE_FOR)}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[name]}")
    if name in _SMALL:
        return getattr(mod, name.replace("-", "_").upper())
    return mod.CONFIG


def list_archs() -> Sequence[str]:
    return ARCHS
