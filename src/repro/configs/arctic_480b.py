"""arctic-480b — MoE 128e top-2 + dense residual. [hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    moe=MoEConfig(n_experts=128, top_k=2, expert_d_ff=4864, moe_every=1,
                  dense_residual_d_ff=4864, ep_mode="subgrid", f_sub=2),
    notes="dense-FFN residual branch in parallel with 128e top-2 MoE",
    source="hf:Snowflake/snowflake-arctic-base",
)
