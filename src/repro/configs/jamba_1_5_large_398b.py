"""jamba-1.5-large-398b — Mamba+attn 1:7 interleave, MoE 16e top-2. [arXiv:2403.19887; hf]"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig, HybridConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    moe=MoEConfig(n_experts=16, top_k=2, expert_d_ff=24576, moe_every=2,
                  moe_offset=1, ep_mode="grid"),
    ssm=SSMConfig(kind="mamba", d_state=16, d_conv=4, expand=2, chunk=256),
    hybrid=HybridConfig(period=8, attn_index=4),
    notes="period-8 blocks (attn at index 4, 7 mamba); MoE every 2nd layer; sub-quadratic-dominant (runs long_500k)",
    source="arXiv:2403.19887",
)
