"""The paper's own experiment models (Section 4).

- 3-conv CNN + FC head on CIFAR-10-shaped inputs (Figs. 8, 10, 11, Tables 1-2)
- 4-hidden-layer MLP on flattened images   (Fig. 9, sklearn substitute)
- logistic regression on MNIST-shaped inputs (Fig. 12, RQ7 scale runs)

These are not LM configs; they use the ``small`` family handled by
``repro.models.small``.
"""
from repro.configs.base import ModelConfig

FLSIM_CNN = ModelConfig(
    name="flsim-cnn", family="small", n_layers=3, d_model=64, n_heads=1,
    n_kv_heads=1, d_ff=128, vocab_size=10,
    notes="3 CNN layers + FC classification head, CIFAR-10 shaped (32x32x3)",
    source="paper §4.1",
)

FLSIM_MLP = ModelConfig(
    name="flsim-mlp", family="small", n_layers=4, d_model=256, n_heads=1,
    n_kv_heads=1, d_ff=256, vocab_size=10,
    notes="4-hidden-layer MLP on flattened 32x32x3 images (paper's sklearn stand-in)",
    source="paper §4.2",
)

FLSIM_LOGREG = ModelConfig(
    name="flsim-logreg", family="small", n_layers=0, d_model=784, n_heads=1,
    n_kv_heads=1, d_ff=0, vocab_size=10,
    notes="logistic regression, MNIST shaped (paper §4.6 scale experiments)",
    source="paper §4.6",
)
