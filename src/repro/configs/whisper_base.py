"""whisper-base — enc-dec, conv frontend STUB. [arXiv:2212.04356; unverified]

The assignment specifies the transformer backbone only; ``input_specs()`` feeds
precomputed frame embeddings (B, S, d_model) in place of the conv frontend.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,          # decoder layers
    n_enc_layers=6,      # encoder layers
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    dec_len_ratio=8,
    input_kind="frames",
    notes="enc-dec; conv frontend stubbed with precomputed frame embeddings",
    source="arXiv:2212.04356",
)
