"""Reduced configs: same family/topology, tiny dims — for CPU smoke tests.

Dims are kept divisible by 4 on every shardable axis so the same reduced
configs also drive the small-mesh (2x2 / 4x2) shard_map equivalence tests.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import (HybridConfig, MLAConfig, ModelConfig,
                                MoEConfig, SSMConfig)


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    if cfg.family == "small":
        return cfg
    kw = dict(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
    )
    if cfg.attn_type == "mla":
        kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                              qk_nope_head_dim=8, qk_rope_head_dim=8,
                              v_head_dim=8)
        kw["head_dim"] = 0
    if cfg.moe is not None:
        # subgrid packing must tile the (2 x 2) test mesh: E * f_sub = 4
        n_exp = 2 if cfg.moe.ep_mode == "subgrid" else 8
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=n_exp, top_k=2, expert_d_ff=32,
            dense_residual_d_ff=32 if cfg.moe.dense_residual_d_ff else 0,
            capacity_factor=2.0)
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=4, d_conv=4, chunk=32,
            slstm_every=2, dt_rank=8)
        if cfg.family == "ssm":
            kw["d_ff"] = 0
            kw["n_layers"] = 2       # one period of 2 (1 mLSTM + 1 sLSTM)
    if cfg.family == "hybrid":
        kw["hybrid"] = HybridConfig(period=4, attn_index=2)
        kw["n_layers"] = 4
        kw["moe"] = dataclasses.replace(kw["moe"], moe_every=2, moe_offset=1)
    if cfg.family == "encdec":
        kw["n_enc_layers"] = 2
    return dataclasses.replace(cfg, **kw, name=cfg.name + "-reduced")
