"""xlstm-125m — sLSTM + mLSTM blocks. [arXiv:2405.04517; unverified]

d_ff=0 per the assignment: blocks use their own up-projection (proj_factor 2).
One sLSTM block per 4 (rest mLSTM) — documented simplification of the paper's
[7:1] mixing.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    ssm=SSMConfig(kind="xlstm", slstm_every=4, proj_factor=2.0, chunk=256),
    notes="sLSTM + mLSTM blocks; sub-quadratic (runs long_500k)",
    source="arXiv:2405.04517",
)
