"""chameleon-34b — early-fusion VLM; VQ image tokens. [arXiv:2405.09818; unverified]

Early fusion means image patches arrive as VQ codes inside the ordinary token
vocabulary (65536 covers text + image codes); the VQ tokenizer frontend is a
STUB — ``input_specs()`` provides token ids directly.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="dense",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,
    notes="early-fusion VLM; VQ image tokens = ordinary ids (frontend stubbed)",
    source="arXiv:2405.09818",
)
