"""qwen3-moe-30b-a3b — MoE 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,            # per-expert FFN width
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    moe=MoEConfig(n_experts=128, top_k=8, expert_d_ff=768, moe_every=1),
    rope_theta=1_000_000.0,
    notes="128 experts top-8, every layer MoE",
    source="hf:Qwen/Qwen3-30B-A3B",
)
