"""Recursive HLO cost model over the compiled (post-SPMD, post-fusion) text.

XLA's CPU ``cost_analysis()`` counts while-loop bodies ONCE, which makes it
useless for scanned layer stacks. This walker parses ``compiled.as_text()``
into per-computation symbol tables and computes, with while-loop trip-count
multiplication:

  flops            — 2*numel(result)*K for every dot (K = contracted size),
                     counted in all computations (incl. fusion bodies);
  hbm_bytes        — operand+result bytes of top-level ops (fusion ops count
                     their parameters/results only => post-fusion traffic);
  collective bytes — per collective kind, with replica-group-aware per-chip
                     traffic estimates (AG/A2A: r*(g-1)/g, AR: 2r(g-1)/g,
                     RS: r*(g-1), permute: r).

Shapes in the module are per-device, so every number is per-chip.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"%([\w.\-]+)")


def _parse_shape_list(seg: str):
    """[(dtype, [dims...]), ...] for every TYPE[dims] in the segment."""
    out = []
    for dt, dims in _SHAPE_RE.findall(seg):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _nbytes(shapes) -> int:
    return sum(_DTYPE_BYTES[dt] * math.prod(d) if d else _DTYPE_BYTES[dt]
               for dt, d in shapes)


@dataclasses.dataclass
class Line:
    name: str
    result_shapes: list          # [(dtype, dims)]
    op: str
    rest: str                    # text after the opname '('


_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")


def parse_module(text: str):
    """-> dict comp_name -> list[Line]"""
    comps: dict[str, list] = {}
    cur = None
    for raw in text.splitlines():
        line = raw.rstrip()
        ls = line.strip()
        if not ls:
            continue
        # computation headers look like: %name (params...) -> type {   or
        # ENTRY %name ... {
        if ls.endswith("{") and ("(" in ls) and ("=" not in ls.split("(")[0]):
            m = _NAME_RE.search(ls)
            cur = m.group(1) if m else f"comp{len(comps)}"
            comps[cur] = []
            continue
        if ls.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(ls)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # rhs: TYPE op-name(args), attrs...
        # find the op name: first identifier followed by '(' after the type
        tm = re.match(r"^((?:\([^)]*\)|[a-z0-9_]+\[[0-9,]*\]\S*)\s+)+"
                      r"([a-z][\w\-]*)\(", rhs)
        if not tm:
            continue
        op = tm.group(2)
        type_seg = rhs[:tm.start(2)]
        comps[cur].append(Line(name, _parse_shape_list(type_seg), op,
                               rhs[tm.end(2):]))
    return comps


def _group_size(rest: str, default: int = 2) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rest)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9, ]*)\}", rest)
    if m:
        return m.group(1).count(",") + 1
    return default


def _trip_count(comps, cond_name: str):
    """Trip count from the while condition: compare(*, constant(N))."""
    for ln in comps.get(cond_name, ()):
        if ln.op == "compare":
            m = re.findall(r"constant\((\d+)\)", ln.rest)
            if m:
                return int(m[-1])
    # search constants referenced in the condition computation
    for ln in comps.get(cond_name, ()):
        if ln.op == "constant":
            m = re.match(r"\((\d+)\)", ln.rest.strip())
            if m:
                return int(m.group(1))
    return 1


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    # traffic inside while-bodies nested >= 2 deep (inner attention / ssm /
    # ring loops). On TPU these loops are Pallas kernels whose intermediates
    # stay in VMEM, so (hbm_bytes - hbm_inner_bytes) is the kernelized HBM
    # floor; hbm_bytes is the as-compiled (no inter-op reuse) ceiling.
    hbm_inner_bytes: float = 0.0
    coll_traffic: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    coll_counts: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def scaled(self, k: float) -> "Cost":
        c = Cost(self.flops * k, self.hbm_bytes * k, self.hbm_inner_bytes * k)
        for kk, v in self.coll_traffic.items():
            c.coll_traffic[kk] = v * k
        for kk, v in self.coll_counts.items():
            c.coll_counts[kk] = v * k
        return c

    def add(self, o: "Cost"):
        self.flops += o.flops
        self.hbm_bytes += o.hbm_bytes
        self.hbm_inner_bytes += o.hbm_inner_bytes
        for kk, v in o.coll_traffic.items():
            self.coll_traffic[kk] += v
        for kk, v in o.coll_counts.items():
            self.coll_counts[kk] += v


def _dot_flops(ln: Line, table: dict) -> float:
    out_numel = sum(math.prod(d) if d else 1 for _, d in ln.result_shapes)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ln.rest)
    args = _NAME_RE.findall(ln.rest.split("),")[0])
    K = 1
    if m and args:
        lhs = table.get(args[0])
        if lhs:
            dims = lhs[0][1]
            for idx in (int(i) for i in m.group(1).split(",") if i):
                if idx < len(dims):
                    K *= dims[idx]
    return 2.0 * out_numel * K


def _call_target(rest: str, attr: str):
    m = re.search(attr + r"=%?([\w.\-]+)", rest)
    return m.group(1) if m else None


def analyze(text: str, entry: str | None = None) -> Cost:
    comps = parse_module(text)
    if not comps:
        return Cost()
    # entry = computation with 'main' in name, else the largest
    if entry is None:
        cands = [c for c in comps if "main" in c]
        entry = cands[0] if cands else max(comps, key=lambda c: len(comps[c]))
    tables = {c: {ln.name: ln.result_shapes for ln in lines}
              for c, lines in comps.items()}
    memo: dict[str, Cost] = {}

    # flops inside fusion bodies attribute to the fusion call site; find the
    # computation each fusion body belongs to lazily via the call attr.

    def comp_cost(cname: str, top: bool, depth: int = 0) -> Cost:
        key = f"{cname}|{top}|{min(depth, 2)}"
        if key in memo:
            return memo[key]
        cost = Cost()
        table = tables.get(cname, {})
        inner = depth >= 2

        def hbm(nb):
            cost.hbm_bytes += nb
            if inner:
                cost.hbm_inner_bytes += nb

        for ln in comps.get(cname, ()):
            if ln.op == "dot":
                cost.flops += _dot_flops(ln, table)
            elif ln.op == "convolution":
                # rough: 2 * out_numel * (kernel numel / out_channels)
                cost.flops += 2.0 * sum(
                    math.prod(d) for _, d in ln.result_shapes)
            if ln.op == "while":
                body = _call_target(ln.rest, "body")
                cond = _call_target(ln.rest, "condition")
                trips = _trip_count(comps, cond) if cond else 1
                if body:
                    cost.add(comp_cost(body, top, depth + 1)
                             .scaled(max(trips, 1)))
                continue
            if ln.op in ("call", "conditional", "async-start"):
                tgt = _call_target(ln.rest, "to_apply") or \
                    _call_target(ln.rest, "called_computation")
                if tgt:
                    cost.add(comp_cost(tgt, top, depth))
                continue
            if ln.op == "fusion":
                tgt = _call_target(ln.rest, "calls")
                if tgt:
                    fin = comp_cost(tgt, False, depth)
                    cost.flops += fin.flops
                    cost.add(Cost(0, 0, 0, fin.coll_traffic, fin.coll_counts))
                if top:
                    # post-fusion HBM traffic: fusion operands + results
                    opshapes = _parse_shape_list(ln.rest)
                    hbm(_nbytes(ln.result_shapes) +
                        sum(_nbytes([s]) for s in opshapes))
                continue
            if top and ln.op not in ("parameter", "constant", "tuple",
                                     "get-tuple-element", "bitcast"):
                nb = _nbytes(ln.result_shapes)
                # operand bytes via symbol table
                args = _NAME_RE.findall(ln.rest.split(")")[0])
                for a in args:
                    if a in table:
                        nb += _nbytes(table[a])
                hbm(nb)
            if ln.op in COLLECTIVES or any(
                    ln.op == c + "-start" for c in COLLECTIVES):
                kind = ln.op.replace("-start", "")
                g = _group_size(ln.rest)
                r = _nbytes(ln.result_shapes)
                cost.coll_counts[kind] += 1
                if kind in ("all-gather", "all-to-all"):
                    cost.coll_traffic[kind] += r * (g - 1) / g
                elif kind == "all-reduce":
                    cost.coll_traffic[kind] += 2 * r * (g - 1) / g
                elif kind == "reduce-scatter":
                    cost.coll_traffic[kind] += r * (g - 1)
                else:
                    cost.coll_traffic[kind] += r
        memo[key] = cost
        return cost

    return comp_cost(entry, True)
