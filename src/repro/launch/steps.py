"""Step builders: shard_map-wrapped train / prefill / decode programs plus
ShapeDtypeStruct input factories for every (arch x shape x mesh) cell.

This is the single source of truth used by dryrun.py, train.py and serve.py.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import (FLConfig, ModelConfig, ShapeConfig, get_config)
from repro.core.rounds import build_spatial_round, build_temporal_round
from repro.core.strategies import get_strategy
from repro.models import model_zoo, transformer
from repro.models.attention import KVCache, LatentCache
from repro.models.ssm import MLSTMState, MambaState, SLSTMState
from repro.sharding import specs as sspecs
from repro.sharding.axes import AxisCtx

try:
    from jax.experimental.shard_map import shard_map
except ImportError:  # newer jax
    from jax.sharding import shard_map


def mesh_ctx(mesh) -> AxisCtx:
    names = mesh.axis_names
    return AxisCtx(data="data" if "data" in names else None,
                   model="model" if "model" in names else None,
                   pod="pod" if "pod" in names else None)


def _axis_sizes(mesh):
    return list(zip(mesh.axis_names, mesh.devices.shape))


def _batch_axes(mesh, global_batch: int, spatial: bool = False,
                order=("pod", "data")):
    """Axes over which the leading batch dim shards (divisibility-checked)."""
    if spatial:
        order = ("data", "model")
    sizes = dict(_axis_sizes(mesh))
    axes, n = [], 1
    for a in order:
        if a in sizes and global_batch % (n * sizes[a]) == 0:
            axes.append(a)
            n *= sizes[a]
    return tuple(axes)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def _sds(mesh, shape, spec, dtype):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def batch_struct(cfg: ModelConfig, shape: ShapeConfig, mesh,
                 lead: tuple = (), spatial: bool = False):
    """Token/label (or frame) stand-ins for one step. ``lead`` prepends
    (cohort, steps) dims replicated/client-sharded by the caller.

    The sequence dim shards over ``model`` (SP) except: the pure-SSM family
    (sLSTM/mLSTM recurrences cross shard boundaries — full sequences, batch
    over data) and hybrid TRAINING (the mamba cross-shard state handoff is
    AD-hostile, so batch shards over data x model instead; prefill keeps SP
    with the forward-only handoff). See transformer.seq_sharded_in."""
    from repro.models.transformer import seq_sharded_in
    B, S = shape.global_batch, shape.seq_len
    sharded_seq = seq_sharded_in(cfg, shape.kind)
    order = ("data", "model", "pod") if (
        shape.kind == "train" and not sharded_seq
        and cfg.family != "ssm") else ("pod", "data")
    baxes = _batch_axes(mesh, B, spatial, order=order)
    bspec = baxes if baxes else None
    seq = "model" if sharded_seq and "model" not in baxes else None
    nlead = len(lead)
    pad = (None,) * nlead

    def tok(shp, spec, dt=jnp.int32):
        return _sds(mesh, lead + shp, P(*pad, *spec), dt)

    if cfg.family == "encdec":
        S_dec = S // cfg.dec_len_ratio
        return {
            "frames": tok((B, S, cfg.d_model), (bspec, seq, None),
                          jnp.bfloat16),
            "tokens": tok((B, S_dec), (bspec, seq)),
            "labels": tok((B, S_dec), (bspec, seq)),
        }
    return {
        "tokens": tok((B, S), (bspec, seq)),
        "labels": tok((B, S), (bspec, seq)),
    }


def param_structs(cfg: ModelConfig, mesh, phase: str, dtype=jnp.bfloat16):
    shapes = transformer.param_shapes(cfg)
    specs = sspecs.param_specs(cfg, phase)
    return jax.tree.map(
        lambda sh, sp: _sds(mesh, sh, sp, dtype), shapes, specs,
        is_leaf=lambda x: isinstance(x, tuple))


# -- decode caches -----------------------------------------------------------

def cache_tree(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """(structs, specs) for a full decode cache at context length S."""
    model = model_zoo.build(cfg)
    B, S = shape.global_batch, shape.seq_len
    ctx0 = AxisCtx()
    if cfg.family == "encdec":
        S_dec = S // cfg.dec_len_ratio
        batch = {
            "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16),
            "tokens": jax.ShapeDtypeStruct((B, S_dec), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S_dec), jnp.int32),
        }
    else:
        batch = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
    params = jax.tree.map(
        lambda sh: jax.ShapeDtypeStruct(sh, jnp.bfloat16),
        transformer.param_shapes(cfg),
        is_leaf=lambda x: isinstance(x, tuple))
    caches, _, _ = jax.eval_shape(
        lambda p, b: model.prefill(ctx0, p, b), params, batch)
    baxes = _batch_axes(mesh, B)
    bspec = baxes if baxes else None
    tp = sspecs.placement_for(cfg) == "temporal"

    def spec_for(path, leaf):
        # leaf shapes: (L, B, ...) stacked; classify by enclosing cache type
        names = [getattr(k, "name", getattr(k, "key", "")) for k in path]
        nd = len(leaf.shape)
        sp = [None] * nd
        # find batch dim: the dim whose size == B right after stack dims
        bdim = 1
        sp[bdim] = bspec
        if any(n in ("k", "v", "ckv", "krope") for n in names):
            sp[2] = "model"                      # sequence-sharded cache
        elif "h" in names or any(n == "conv" for n in names):
            # mamba state: channels dim model-sharded in tp decode
            cdim = 2 if "h" in names else 3
            if tp and leaf.shape[cdim] % 16 == 0:
                sp[cdim] = "model"
        # mlstm / slstm states stay replicated over model
        return P(*sp)

    flat = jax.tree_util.tree_flatten_with_path(caches)
    specs = jax.tree.unflatten(flat[1], [spec_for(p, l) for p, l in flat[0]])
    structs = jax.tree.map(
        lambda l, sp: _sds(mesh, l.shape, sp, l.dtype), caches, specs)
    return structs, specs


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BuiltStep:
    fn: Any                   # jit-able callable over GLOBAL arrays
    inputs: tuple             # ShapeDtypeStructs (global, with shardings)
    kind: str
    donate: tuple = ()        # argnums whose buffers the step may reuse


def make_train_step(cfg: ModelConfig, shape: ShapeConfig, mesh,
                    fl: Optional[FLConfig] = None) -> BuiltStep:
    fl = fl or FLConfig(strategy="fedavg", local_epochs=1, client_lr=1e-2)
    model = model_zoo.build(cfg)
    strategy = get_strategy(fl)
    ctx = mesh_ctx(mesh)
    spatial = sspecs.placement_for(cfg) == "spatial"
    sizes = dict(_axis_sizes(mesh))

    if spatial:
        round_fn = build_spatial_round(model, strategy, fl)
        n_clients = sizes.get("data", 1) * sizes.get("model", 1)
        pspec = sspecs.param_specs(cfg, "spatial")
        state_specs = {"params": pspec, "server":
                       jax.tree.map(lambda _: P(), strategy.server_state_init(
                           transformer.param_shapes(cfg))),
                       "clients": ()}
        # batch: (C, steps, B_c, ...) with C over the client grid
        B, S = shape.global_batch, shape.seq_len
        B_c = max(B // n_clients, 1)
        lead = (n_clients, 1, B_c)
        cspec = ("data", "model")
        if cfg.family == "encdec":
            S_dec = S // cfg.dec_len_ratio
            batch = {
                "frames": _sds(mesh, lead + (S, cfg.d_model),
                               P(cspec, None, None, None, None), jnp.bfloat16),
                "tokens": _sds(mesh, lead + (S_dec,),
                               P(cspec, None, None, None), jnp.int32),
                "labels": _sds(mesh, lead + (S_dec,),
                               P(cspec, None, None, None), jnp.int32),
            }
        else:
            batch = {
                "tokens": _sds(mesh, lead + (S,),
                               P(cspec, None, None, None), jnp.int32),
                "labels": _sds(mesh, lead + (S,),
                               P(cspec, None, None, None), jnp.int32),
            }
        bspecs = jax.tree.map(lambda s: P(cspec, *([None] * (len(s.shape) - 1))),
                              batch)
        weights = _sds(mesh, (n_clients,), P(cspec), jnp.float32)
        wspec = P(cspec)
    else:
        round_fn = build_temporal_round(model, strategy, fl, cfg)
        pspec = sspecs.param_specs(cfg, "fsdp")
        state_specs = {"params": pspec, "server":
                       jax.tree.map(lambda _: P(),
                                    strategy.server_state_init(
                                        transformer.param_shapes(cfg))),
                       "clients": ()}
        bs = batch_struct(cfg, shape, mesh, lead=(1, 1))
        batch = bs
        bspecs = jax.tree.map(lambda s: s.sharding.spec, batch)
        weights = _sds(mesh, (1,), P(None), jnp.float32)
        wspec = P(None)

    params = param_structs(cfg, mesh, "spatial" if spatial else "fsdp")
    # server-state structs mirror params (momenta shard like their params);
    # stateless servers (plain FedAvg) give ().
    if strategy.server_state_init({"_": jnp.zeros(())}):
        server = jax.tree.map(lambda s: s, {"momentum": params}) \
            if strategy.name == "fedavgm" else \
            {"m": params, "v": params, "t": _sds(mesh, (), P(), jnp.int32)}
    else:
        server = ()
    state = {"params": params, "server": server, "clients": ()}
    rng = _sds(mesh, (2,), P(None), jnp.uint32)
    sstate_specs = jax.tree.map(lambda s: s.sharding.spec, state)

    fn = shard_map(
        functools.partial(round_fn, ctx),
        mesh=mesh,
        in_specs=(sstate_specs, bspecs, wspec, P(None)),
        out_specs=(sstate_specs, {"loss": P()}),
        check_rep=False)
    return BuiltStep(fn, (state, batch, weights, rng), "train", donate=(0,))


# ---------------------------------------------------------------------------
# Serve steps
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, shape: ShapeConfig, mesh) -> BuiltStep:
    model = model_zoo.build(cfg)
    ctx = mesh_ctx(mesh)
    # spatial archs keep replicated weights (tiny); big archs ZeRO-3-gather
    spatial = sspecs.placement_for(cfg) == "spatial"
    phase = "spatial" if spatial else "fsdp"
    if spatial:
        ctx = dataclasses.replace(ctx, vocab=None)
    params = param_structs(cfg, mesh, phase)
    batch = batch_struct(cfg, shape, mesh)
    cache_structs, cache_specs = cache_tree(cfg, shape, mesh)
    baxes = _batch_axes(mesh, shape.global_batch)
    bspec = baxes if baxes else None

    def step(p, b):
        gather = sspecs.make_gather_fn(cfg, ctx)
        caches, logits, _ = model.prefill(ctx, p, b, gather)
        return caches, logits

    fn = shard_map(
        step, mesh=mesh,
        in_specs=(jax.tree.map(lambda s: s.sharding.spec, params),
                  jax.tree.map(lambda s: s.sharding.spec, batch)),
        out_specs=(cache_specs, P(bspec, None)),
        check_rep=False)
    return BuiltStep(fn, (params, batch), "prefill")


def make_decode_step(cfg: ModelConfig, shape: ShapeConfig, mesh) -> BuiltStep:
    model = model_zoo.build(cfg)
    ctx = mesh_ctx(mesh)
    tp = sspecs.placement_for(cfg) == "temporal"
    phase = "tp" if tp else "spatial"
    if not tp:
        ctx = dataclasses.replace(ctx, vocab=None)
    params = param_structs(cfg, mesh, phase)
    cache_structs, cache_specs = cache_tree(cfg, shape, mesh)
    B = shape.global_batch
    baxes = _batch_axes(mesh, B)
    bspec = baxes if baxes else None
    tokens = _sds(mesh, (B,), P(bspec), jnp.int32)
    length = _sds(mesh, (B,), P(bspec), jnp.int32)

    def step(p, t, c, ln):
        logits, new_c = model.decode_step(ctx, p, t, c, ln, tp=tp)
        return logits, new_c

    logits_spec = P(bspec, "model" if tp else None)
    fn = shard_map(
        step, mesh=mesh,
        in_specs=(jax.tree.map(lambda s: s.sharding.spec, params),
                  P(bspec), cache_specs, P(bspec)),
        out_specs=(logits_spec, cache_specs),
        check_rep=False)
    return BuiltStep(fn, (params, tokens, cache_structs, length), "decode",
                     donate=(2,))


def make_step_from_cfg(cfg: ModelConfig, shape_cfg: ShapeConfig, mesh,
                       fl: Optional[FLConfig] = None) -> BuiltStep:
    if shape_cfg.kind == "train":
        return make_train_step(cfg, shape_cfg, mesh, fl)
    if shape_cfg.kind == "prefill":
        return make_prefill_step(cfg, shape_cfg, mesh)
    return make_decode_step(cfg, shape_cfg, mesh)


def make_step(arch: str, shape_cfg: ShapeConfig, mesh,
              fl: Optional[FLConfig] = None) -> BuiltStep:
    return make_step_from_cfg(get_config(arch), shape_cfg, mesh, fl)
