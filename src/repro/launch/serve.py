"""Serving launcher: batched prefill + greedy decode loop.

On CPU this drives a reduced model end-to-end (the serving example); on a
TPU mesh the same functions run under the production shardings via
steps.make_prefill_step / make_decode_step.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.configs.reduce import reduced_config
from repro.models import model_zoo
from repro.models.transformer import pad_caches
from repro.sharding.axes import AxisCtx


def generate(model, params, prompts, max_new: int = 16,
             ctx: AxisCtx = AxisCtx()):
    """prompts: (B, S) int32 -> (B, max_new) greedy tokens."""
    B, S = prompts.shape
    batch = {"tokens": prompts, "labels": jnp.zeros_like(prompts)}
    caches, logits, _ = jax.jit(
        lambda p, b: model.prefill(ctx, p, b))(params, batch)
    caches = pad_caches(caches, max_new)
    step = jax.jit(lambda p, t, c, ln: model.decode_step(
        ctx, p, t, c, ln, tp=False))
    out = []
    tok = model.greedy_token(ctx, logits)
    length = jnp.full((B,), S, jnp.int32)
    for i in range(max_new):
        out.append(tok)
        logits, caches = step(params, tok, caches, length)
        tok = model.greedy_token(ctx, logits)
        length = length + 1
    return jnp.stack(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-34b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    cfg = reduced_config(get_config(args.arch))
    model = model_zoo.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    toks = generate(model, params, prompts, args.max_new)
    dt = time.time() - t0
    print(f"arch={cfg.name} generated {toks.shape} in {dt:.1f}s "
          f"({args.batch * args.max_new / dt:.1f} tok/s)")
    print(np.asarray(toks)[:2])


if __name__ == "__main__":
    main()
