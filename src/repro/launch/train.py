"""FL training launcher.

Two paths:
- small archs (paper models): the host Executor (Alg. 1) with spatial rounds.
- LM archs: temporal rounds via the same step builders the dry-run compiles,
  on whatever mesh the process sees (CPU: meshless; TPU pod: production mesh).

Usage:
  PYTHONPATH=src python -m repro.launch.train --job examples/jobs/quickstart.yaml
  PYTHONPATH=src python -m repro.launch.train --arch yi-34b --dry-run
"""
from __future__ import annotations

import argparse
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--job", default=None, help="job yaml (paper Fig. 2)")
    ap.add_argument("--arch", default="flsim-cnn")
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced config for LM archs")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--dry-run", action="store_true",
                    help="lower+compile only (delegates to launch.dryrun)")
    args = ap.parse_args()

    if args.dry_run:
        from repro.launch import dryrun
        sys.argv = ["dryrun", "--arch", args.arch, "--shape", "train_4k"]
        return dryrun.main()

    from repro.core.jobs import load_job
    from repro.runtime.executor import Executor

    if args.job:
        job = load_job(args.job)
    else:
        job = load_job({
            "name": f"train-{args.arch}",
            "model": {"arch": args.arch, "reduced": args.reduced},
            "dataset": {"dataset": "synthetic_vision", "n_items": 512},
            "strategy": {"strategy": "fedavg",
                         "train_params": {"n_clients": args.clients,
                                          "client_lr": 0.05,
                                          "local_epochs": 1,
                                          "rounds": args.rounds,
                                          "checkpoint_every": 2}},
        })
    ex = Executor(job, ckpt_dir=args.ckpt_dir).scaffold()
    state, logger = ex.run(args.rounds)
    print(logger.dashboard())
    return state


if __name__ == "__main__":
    main()
