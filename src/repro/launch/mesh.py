"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: (16, 16) = 256 chips, axes
("data", "model"); multi-pod: (2, 16, 16) = 512 chips with a leading "pod"
axis (hierarchical-FL tier / DP replica; collectives over it model the
cross-pod DCN hop).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over forced host devices for unit tests."""
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


# TPU v5e hardware constants (roofline):
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
