"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: (16, 16) = 256 chips, axes
("data", "model"); multi-pod: (2, 16, 16) = 512 chips with a leading "pod"
axis (hierarchical-FL tier / DP replica; collectives over it model the
cross-pod DCN hop).
"""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    """jax.make_mesh across jax versions: AxisType (and the axis_types
    kwarg) only exist on newer jax; older jax is implicitly all-Auto."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over forced host devices for unit tests."""
    return _mesh(shape, axes)


def lane_mesh(n=0):
    """1-D device mesh over the campaign sweep axis (``"lanes"``).

    Sweep trajectories are embarrassingly parallel, so the leading (S,) dim
    of every campaign plane (data idx/len, schedules, scalars, alive mask,
    stacked model state) shards cleanly over devices — each device advances
    S/n lanes of the same compiled program with zero collectives.
    ``n`` is a device count — or a ``configs.base.MeshConfig``, whose
    ``lanes`` axis is that count. ``n = 0`` takes every local device. On
    CPU, ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` fakes a
    multi-device host for tests and benches (see README "Device-parallel
    campaigns").
    """
    n = int(getattr(n, "lanes", n)) or jax.local_device_count()
    if n > jax.device_count():
        raise ValueError(
            f"lane_mesh({n}) wants {n} devices but only "
            f"{jax.device_count()} are visible; on CPU, set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} before "
            "jax initializes to fake a multi-device host")
    return _mesh((n,), ("lanes",))


def lane_sharding(mesh, replicated: bool = False):
    """NamedSharding placing the leading dim over ``lanes`` (or replicating:
    the campaign's concatenated data roots and unique schedules serve every
    lane from one logical copy per device)."""
    spec = (jax.sharding.PartitionSpec() if replicated
            else jax.sharding.PartitionSpec("lanes"))
    return jax.sharding.NamedSharding(mesh, spec)


def shard_lanes(tree, mesh, axes=None):
    """Place a campaign plane pytree on a lane mesh.

    With ``axes`` (a dict like ``data/pipeline.DEDUP_STAGED_AXES``), leaves
    mapped over the sweep axis (entry ``0``) shard their leading dim over
    ``lanes`` and unmapped leaves (entry ``None``) replicate; without it
    every leaf lane-shards. Identity when ``mesh`` is None, so single-device
    campaigns never touch placement."""
    if mesh is None:
        return tree
    lane = lane_sharding(mesh)
    repl = lane_sharding(mesh, replicated=True)
    if axes is None:
        return jax.tree.map(lambda t: jax.device_put(t, lane), tree)
    return {k: jax.device_put(v, repl if axes.get(k) is None else lane)
            for k, v in tree.items()}


def mesh_context(mesh):
    """Ambient-mesh context across jax versions: ``jax.set_mesh`` on newer
    jax; on older jax the Mesh object is itself the context manager."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


# TPU v5e hardware constants (roofline):
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
