"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: (16, 16) = 256 chips, axes
("data", "model"); multi-pod: (2, 16, 16) = 512 chips with a leading "pod"
axis (hierarchical-FL tier / DP replica; collectives over it model the
cross-pod DCN hop).
"""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    """jax.make_mesh across jax versions: AxisType (and the axis_types
    kwarg) only exist on newer jax; older jax is implicitly all-Auto."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over forced host devices for unit tests."""
    return _mesh(shape, axes)


def mesh_context(mesh):
    """Ambient-mesh context across jax versions: ``jax.set_mesh`` on newer
    jax; on older jax the Mesh object is itself the context manager."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


# TPU v5e hardware constants (roofline):
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
