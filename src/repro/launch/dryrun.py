import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces:
- proof of compilation on the production meshes (16x16 and 2x16x16),
- memory_analysis (fits-on-chip evidence),
- cost_analysis flops/bytes,
- the collective schedule parsed from the compiled HLO.

``--layers k`` compiles with a truncated layer stack; the roofline harness
compiles two small depths and extrapolates per-layer costs (XLA's CPU cost
analysis counts while-loop bodies once — see benchmarks/roofline.py).

Results are cached as JSON under results/dryrun/.
"""
import argparse
import dataclasses
import json
import pathlib
import re
import sys
import time
from collections import Counter

import jax

from repro.configs.base import (ARCHS, SHAPES, get_config, shapes_for)
from repro.launch import mesh as mesh_mod
from repro.launch import steps as steps_mod

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}


_SHAPE_PAT = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(segment: str) -> int:
    nbytes = 0
    for dt, dims in _SHAPE_PAT.findall(segment):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        nbytes += n * _DTYPE_BYTES[dt]
    return nbytes


def _group_size(line: str) -> int:
    i = line.find("replica_groups=")
    if i < 0:
        return 2
    seg = line[i:i + 4000]
    # forms: {{0,1,2,...},{...}} or [16,32]<=[...] (iota groups)
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", seg)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9, ]*)\}", seg)
    if m:
        return m.group(1).count(",") + 1
    return 2


def collective_bytes(hlo_text: str) -> dict:
    """Per-chip collective traffic from the (post-SPMD) compiled HLO.

    Shapes in the compiled module are PER-DEVICE. For each collective op the
    RESULT shape bytes and replica-group size g give the estimated per-chip
    link traffic: AG/A2A ~ result*(g-1)/g, AR ~ 2*result*(g-1)/g,
    RS ~ result*(g-1), permute ~ result. while-loop bodies appear once (the
    roofline harness scales by trip count via depth extrapolation)."""
    out = {c: 0.0 for c in COLLECTIVES}
    raw = {c: 0 for c in COLLECTIVES}
    counts = Counter()
    for line in hlo_text.splitlines():
        ls = line.lstrip()
        if not (ls.startswith("%") or ls.startswith("ROOT")):
            continue
        eq = ls.find(" = ")
        if eq < 0:
            continue
        rhs = ls[eq + 3:]
        kind = None
        for c in COLLECTIVES:
            j = rhs.find(c + "(")
            if j < 0:
                j = rhs.find(c + "-start(")
            if j >= 0:
                kind = c
                type_seg = rhs[:j]
                break
        if kind is None:
            continue
        counts[kind] += 1
        nbytes = _shape_bytes(type_seg)
        g = _group_size(line)
        raw[kind] += nbytes
        if kind in ("all-gather", "all-to-all"):
            out[kind] += nbytes * (g - 1) / g
        elif kind == "all-reduce":
            out[kind] += 2 * nbytes * (g - 1) / g
        elif kind == "reduce-scatter":
            out[kind] += nbytes * (g - 1)
        else:
            out[kind] += nbytes
    return {"traffic_bytes": out, "result_bytes": raw, "counts": dict(counts)}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             layers: int | None = None, verbose: bool = True) -> dict:
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    if layers:
        kw = {"n_layers": layers}
        if cfg.family == "encdec":
            kw["n_enc_layers"] = layers
        cfg = cfg.replace(**kw)
    shape = SHAPES[shape_name]
    t0 = time.time()
    built = steps_mod.make_step_from_cfg(cfg, shape, mesh)
    from repro.launch.mesh import mesh_context
    with mesh_context(mesh):
        lowered = jax.jit(built.fn,
                          donate_argnums=built.donate).lower(*built.inputs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind, "layers": layers or cfg.n_layers,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "args_GiB": ma.argument_size_in_bytes / 2**30,
            "output_GiB": ma.output_size_in_bytes / 2**30,
            "temp_GiB": ma.temp_size_in_bytes / 2**30,
            "peak_GiB": (ma.argument_size_in_bytes
                         + ma.temp_size_in_bytes) / 2**30,
        },
        "cost": {"flops": ca.get("flops", 0.0),
                 "bytes_accessed": ca.get("bytes accessed", 0.0)},
        "collectives": coll,
    }
    if verbose:
        print(f"[{arch} x {shape_name} x {rec['mesh']} L={rec['layers']}] "
              f"compile {t_compile:.1f}s  args {rec['memory']['args_GiB']:.2f}G "
              f"temp {rec['memory']['temp_GiB']:.2f}G  "
              f"flops {rec['cost']['flops']:.3e}  "
              f"coll {coll['counts']}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--layers", type=int, default=0,
                    help="truncate layer stacks (roofline extrapolation)")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    RESULTS.mkdir(parents=True, exist_ok=True)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch in archs:
        names = shapes_for(arch) if args.shape == "all" else args.shape.split(",")
        for shape_name in names:
            if shape_name not in shapes_for(arch):
                continue
            for mp in meshes:
                key = f"{arch}__{shape_name}__{'mp' if mp else 'sp'}"
                if args.layers:
                    key += f"__L{args.layers}"
                if args.tag:
                    key += f"__{args.tag}"
                out = RESULTS / f"{key}.json"
                try:
                    rec = run_cell(arch, shape_name, mp,
                                   layers=args.layers or None)
                    out.write_text(json.dumps(rec, indent=1))
                except Exception as e:  # noqa
                    failures.append((key, repr(e)[:400]))
                    print(f"FAIL {key}: {e!r}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for k, e in failures:
            print(" ", k, e)
        sys.exit(1)
    print("\nAll requested dry-run cells compiled.")


if __name__ == "__main__":
    main()
