"""Deterministic synthetic data pipelines (FLsim Dataset contract).

Two root datasets:
- ``SyntheticVision``: CIFAR-10 / MNIST-shaped classification data with a
  planted linear-signal so models can actually learn (losses decrease and
  accuracies separate across strategies, as the paper's figures need).
- ``SyntheticLM``: token streams with an order-k Markov structure for the
  LM-family architectures.

Every pipeline exposes prepare_root_dataset / distribute_into_chunks /
client_batches with a position cursor, so checkpoints can resume the exact
data order (fault tolerance).
"""
from __future__ import annotations

import concurrent.futures
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import determinism
from repro.data import partition as part_mod


# ---------------------------------------------------------------------------
# Device-resident staging (the "download once" half of the driver contract)
# ---------------------------------------------------------------------------

def _pad_idx(parts, lmax: int) -> np.ndarray:
    """Ragged per-client index lists -> dense (C, lmax) int32 by cyclic
    repetition. The wrap never biases sampling: gather positions are drawn
    in [0, true len), so pad columns past a client's length are never read —
    which also makes the padding width itself trajectory-invariant."""
    idx = np.zeros((len(parts), lmax), np.int32)
    for c, p in enumerate(parts):
        if len(p):
            reps = int(np.ceil(lmax / len(p)))
            idx[c] = np.concatenate([p] * reps)[:lmax]
    return idx


def stage_partitions(x, y, parts):
    """One-time device staging of the full root dataset + client partitions.

    The ragged per-client index lists are padded to a dense (C, Lmax) int32
    matrix by cyclic repetition (a client with fewer items than the pad just
    wraps; the wrap never biases sampling because the on-device gather draws
    positions modulo the *true* length). Returns a dict of device arrays:

      x    (N, ...)  root features        y    (N,)      root labels
      idx  (C, Lmax) padded item indices  len  (C,)      true partition sizes

    ``len`` doubles as the FedAvg base weight, so zero-item clients get zero
    weight automatically.
    """
    lmax = max(max((len(p) for p in parts), default=1), 1)
    lens = np.asarray([len(p) for p in parts], np.int32)
    return {"x": jnp.asarray(x), "y": jnp.asarray(y),
            "idx": jnp.asarray(_pad_idx(parts, lmax)),
            "len": jnp.asarray(lens)}


def stage_partitions_stacked(trajectories):
    """Stage S trajectories' datasets as one stacked device residency.

    ``trajectories`` is a list of (x, y, parts) triples — one per campaign
    trajectory (different seeds and/or Dirichlet alphas give different root
    data and/or partitions; identical triples are simply duplicated). All
    trajectories must share n_items and n_clients (sweeps vary distribution,
    not problem size). Returns the ``stage_partitions`` dict with a leading
    sweep dim on every leaf:

      x (S, N, ...)   y (S, N)   idx (S, C, Lmax)   len (S, C)

    Lmax is the max over trajectories; because gather positions are drawn in
    [0, len), the wider shared pad is unobservable, so lane ``s`` of the
    stacked gather is bitwise the trajectory's own single staging.
    """
    n_clients = {len(parts) for _, _, parts in trajectories}
    if len(n_clients) != 1:
        raise ValueError(f"trajectories disagree on n_clients: {n_clients}")
    lmax = max(max((max((len(p) for p in parts), default=1), 1)
                   for _, _, parts in trajectories))
    xs = np.stack([np.asarray(x) for x, _, _ in trajectories])
    ys = np.stack([np.asarray(y) for _, y, _ in trajectories])
    idx = np.stack([_pad_idx(parts, lmax) for _, _, parts in trajectories])
    lens = np.stack([np.asarray([len(p) for p in parts], np.int32)
                     for _, _, parts in trajectories])
    return {"x": jnp.asarray(xs), "y": jnp.asarray(ys),
            "idx": jnp.asarray(idx), "len": jnp.asarray(lens)}


# Per-leaf vmap axes for a deduped campaign staging: the concatenated root
# (x, y) is shared across lanes (no sweep axis), only the small per-lane
# index/length planes carry the leading (S,) dim.
DEDUP_STAGED_AXES = {"x": None, "y": None, "idx": 0, "len": 0}


def stage_partitions_dedup(trajectories, keys=None, mesh=None):
    """Stage S trajectories with the shared root datasets deduplicated.

    ``stage_partitions_stacked`` duplicates the root dataset S times even
    when every lane shares it (any scalar-only sweep) — the ROADMAP memory
    item. Here lanes that share a data-plane triple share ONE device copy:
    the unique roots concatenate along the item axis, and each lane's padded
    index matrix is offset into the concatenation, which IS the
    lane->dataset indirection — the gather functions stay untouched and the
    drawn batches are bitwise identical (positions are drawn in
    [0, true len) and the offset just relocates the same bytes). Returns
    ``(staged, lane_ds)``:

      x ((sum_u N_u), ...)  y ((sum_u N_u),)   shared concatenated roots
      idx (S, C, Lmax)      len (S, C)          per-lane (offset) planes

    plus ``lane_ds`` (S,) int32 mapping each lane to its unique dataset (for
    introspection/tests; the indirection itself is baked into ``idx``).
    ``keys`` are optional hashable dedup keys per trajectory (the campaign
    passes its staging-cache keys); identity is the default.

    ``mesh`` (a ``launch/mesh.lane_mesh``) places the staging for a
    device-parallel campaign: the concatenated roots replicate on every
    device, the per-lane ``idx``/``len`` planes shard their leading (S,)
    dim over the ``lanes`` axis — exactly ``DEDUP_STAGED_AXES`` rendered
    as a sharding. S must then be a multiple of the lane count (the
    campaign pads with dead lanes before staging).
    """
    keys = list(keys) if keys is not None else [id(t) for t in trajectories]
    if len(keys) != len(trajectories):
        raise ValueError(f"{len(keys)} dedup keys for "
                         f"{len(trajectories)} trajectories")
    n_clients = {len(parts) for _, _, parts in trajectories}
    if len(n_clients) != 1:
        raise ValueError(f"trajectories disagree on n_clients: {n_clients}")
    uniq: dict = {}
    roots = []
    for k, t in zip(keys, trajectories):
        if k not in uniq:
            uniq[k] = len(roots)
            roots.append(t)
    lane_ds = np.asarray([uniq[k] for k in keys], np.int32)
    lmax = max(max((max((len(p) for p in parts), default=1), 1)
                   for _, _, parts in roots))
    offsets = np.concatenate(
        [[0], np.cumsum([np.asarray(x).shape[0] for x, _, _ in roots])])
    x_cat = np.concatenate([np.asarray(x) for x, _, _ in roots])
    y_cat = np.concatenate([np.asarray(y) for _, y, _ in roots])
    pads = [_pad_idx(parts, lmax) + np.int32(offsets[u])
            for u, (_, _, parts) in enumerate(roots)]
    lens = [np.asarray([len(p) for p in parts], np.int32)
            for _, _, parts in roots]
    staged = {"x": x_cat, "y": y_cat,
              "idx": np.stack([pads[u] for u in lane_ds]),
              "len": np.stack([lens[u] for u in lane_ds])}
    if mesh is not None:
        from repro.launch.mesh import shard_lanes
        staged = shard_lanes(staged, mesh, DEDUP_STAGED_AXES)
    else:
        staged = {k: jnp.asarray(v) for k, v in staged.items()}
    return staged, lane_ds


def gather_one_client_batch(staged, round_key, client, batch_size: int,
                            n_steps: int):
    """Jittable batch gather for a single (possibly traced) client id.

    Positions are drawn uniformly (with replacement) from the client's true
    partition via ``determinism.batch_key(round_key, client)``, so the batch
    stream for a given (seed, round) is identical no matter how rounds (or
    async events) are chunked into launches. The sync driver vmaps this over
    all clients; the async event scan calls it per arriving client — the two
    are bitwise-identical lanes because threefry draws are
    vectorization-invariant. Returns {"x": (n_steps, B, ...), "y": ...}.
    """
    key = determinism.batch_key(round_key, client)
    maxv = jnp.maximum(staged["len"][client], 1)
    pos = jax.random.randint(key, (n_steps, batch_size), 0, maxv)
    sel = staged["idx"][client, pos]
    return {"x": staged["x"][sel], "y": staged["y"][sel]}


def gather_client_batches(staged, round_key, batch_size: int, n_steps: int):
    """Jittable per-round batch gather for every client, on device.

    One vmap over ``gather_one_client_batch`` (the single source of truth
    for the position draw). Returns {"x": (C, n_steps, B, ...), "y": ...}.
    """
    n_clients = staged["idx"].shape[0]
    return jax.vmap(
        lambda c: gather_one_client_batch(staged, round_key, c, batch_size,
                                          n_steps))(jnp.arange(n_clients))


# ---------------------------------------------------------------------------
# Ragged client plane: cohort slabs + streaming (double-buffered) staging
# ---------------------------------------------------------------------------
#
# With ``max_cohort > 0`` the compiled scan no longer sees the population:
# each round consumes one *slab row* — the sampled cohort's data padded to K
# = max_cohort slots, with the tail zero-weighted. The host replays
# ``faults.cohort_mask`` (already the bitwise host==program contract) ahead
# of the launch, so it knows exactly which clients' shards each chunk needs.
# Two stagers assemble slabs for the SAME compiled program:
#
#   ResidentSlabStager   — root staged on device once, slab gathered on
#                          device per chunk (an async dispatch).
#   StreamingSlabStager  — only the sampled cohorts' shards ever leave host
#                          memory; chunk k+1's host gather + host->device
#                          copy run on a background thread overlapped with
#                          chunk k's scan (double buffering).
#
# Because both feed identical slab bytes into one program, streaming ==
# resident is bitwise by construction, and a population far larger than
# device memory trains at a working set bounded by (rounds_per_launch, K,
# Lmax) — the ``staged_bytes`` telemetry counters report it per chunk.


def slab_nbytes(slab) -> int:
    """Total bytes of a slab (or any pytree of arrays)."""
    return int(sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(slab)))


def gather_slab_batches(slab_row, round_key, batch_size: int, n_steps: int):
    """Jittable per-round batch gather from one cohort slab row.

    The slab analogue of ``gather_client_batches``: slot ``k`` draws
    positions in [0, true len) via ``determinism.batch_key(round_key,
    cid[k])`` — keyed by the *real* client id, not the slot — so a client's
    byte stream is invariant to which slot it lands in and to the slab pad
    width Lmax (pad columns are never read). Returns
    {"x": (K, n_steps, B, ...), "y": ...}.
    """
    def one(k):
        key = determinism.batch_key(round_key, slab_row["cid"][k])
        maxv = jnp.maximum(slab_row["len"][k], 1)
        pos = jax.random.randint(key, (n_steps, batch_size), 0, maxv)
        return {"x": slab_row["x"][k][pos], "y": slab_row["y"][k][pos]}
    return jax.vmap(one)(jnp.arange(slab_row["len"].shape[0]))


def gather_event_batch(row, round_key, client, batch_size: int, n_steps: int):
    """Jittable batch gather from one async event's slab row.

    Same position draw as ``gather_one_client_batch`` (keyed on the real
    client id carried by the schedule), reading the event's staged shard
    instead of the resident root.
    """
    key = determinism.batch_key(round_key, client)
    maxv = jnp.maximum(row["len"], 1)
    pos = jax.random.randint(key, (n_steps, batch_size), 0, maxv)
    return {"x": row["x"][pos], "y": row["y"][pos]}


class _Prefetcher:
    """Single-slot double buffer: one background thread assembles the next
    chunk's slab while the device runs the current one. A request that does
    not match the pending prefetch (resume, end-of-run remainder) just
    assembles synchronously."""

    def __init__(self):
        self.peak_slab_bytes = 0
        self._pool = None
        self._pending = None

    def _submit(self, key, fn):
        if self._pool is None:
            self._pool = concurrent.futures.ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="slab-stager")
        self._pending = (key, self._pool.submit(fn))

    def _take(self, key, fn):
        pend, self._pending = self._pending, None
        if pend is not None and pend[0] == key:
            out = pend[1].result()
        else:
            if pend is not None:
                pend[1].cancel()
            out = fn()
        self.peak_slab_bytes = max(self.peak_slab_bytes, slab_nbytes(out))
        return out


class SlabStager(_Prefetcher):
    """Base cohort-slab stager: host-side cohort planning shared by the
    resident and streaming backends.

    A slab for a chunk of ``n`` rounds starting at absolute round ``start``
    is a dict of scan inputs with leading round dim n:

      x   (n, K, Lmax, ...)  slot features     y   (n, K, Lmax)  slot labels
      len (n, K)  true shard sizes             cid (n, K)        real client ids
      w   (n, K)  FedAvg base weight (len) * cohort mask, 0 on pad slots

    Kept clients fill slots in ascending-id order; pad slots repeat the
    first kept client's shard (zero-weighted, and harmless to train on).
    """

    def __init__(self, fl, fault):
        super().__init__()
        from repro.runtime import faults as faults_mod
        self.fl = fl
        self.fault = fault if fault is not None else faults_mod.FaultModel()
        self.k_slots = int(fl.max_cohort)
        self.lmax = 1
        self.lens = np.zeros((fl.n_clients,), np.int32)

    def plan(self, start: int, n: int):
        """Replay the cohort draw for rounds [start, start+n) on the host.

        Returns (slots (n, K) int32, real (n, K) float32) — exactly the
        clients ``faults.cohort_mask`` keeps inside the compiled program,
        because ``select_cohort`` is the same function.
        """
        from repro.runtime import faults as faults_mod
        fl = self.fl
        target = int(fl.cohort or fl.n_clients)
        ids = np.arange(fl.n_clients)
        slots = np.zeros((n, self.k_slots), np.int32)
        real = np.zeros((n, self.k_slots), np.float32)
        for i in range(n):
            kept = faults_mod.select_cohort(self.fault, start + i, ids,
                                            target, fl.straggler_overprovision)
            if len(kept) > self.k_slots:
                raise ValueError(
                    f"round {start + i} kept {len(kept)} clients but "
                    f"max_cohort={self.k_slots} slots are staged")
            slots[i] = kept[0] if len(kept) else 0
            slots[i, :len(kept)] = kept
            real[i, :len(kept)] = 1.0
        return slots, real

    def widen(self, lmax: int) -> None:
        """Re-pad shards to a wider Lmax (campaign lanes share one width)."""
        self.lmax = max(self.lmax, int(lmax))

    def slab(self, start: int, n: int):
        """The chunk's slab on device (from the prefetch buffer if it hit)."""
        return self._take(("sync", start, n),
                          lambda: self._assemble_chunk(start, n))

    def prefetch(self, start: int, n: int) -> None:
        """Kick background assembly of the next chunk's slab."""
        if n > 0:
            self._submit(("sync", start, n),
                         lambda: self._assemble_chunk(start, n))

    def event_slab(self, clients, tag):
        """Per-event slab rows {"x": (E, Lmax, ...), "y", "len"} for the
        async drivers; ``tag`` keys the prefetch buffer (event window)."""
        clients = np.asarray(clients, np.int32)
        return self._take(("ev", tag),
                          lambda: self._assemble_events(clients))

    def prefetch_events(self, clients, tag) -> None:
        """Kick background assembly of the next event window's rows."""
        clients = np.asarray(clients, np.int32)
        if len(clients):
            self._submit(("ev", tag), lambda: self._assemble_events(clients))

    def _assemble_chunk(self, start, n):
        slots, real = self.plan(start, n)
        return self._assemble(slots, real)


class ResidentSlabStager(SlabStager):
    """Slab stager over a device-resident root: ``stage_partitions`` once,
    then each chunk's slab is an on-device gather (asynchronously
    dispatched, so no prefetch thread is needed)."""

    def __init__(self, x, y, parts, fl, fault):
        super().__init__(fl, fault)
        self._parts = parts
        self.staged = stage_partitions(x, y, parts)
        self.lmax = int(self.staged["idx"].shape[1])
        self.lens = np.asarray(self.staged["len"])
        self.data = (np.asarray(x), np.asarray(y), parts)
        self.resident_bytes = slab_nbytes(self.staged)
        self.device_bytes = self.resident_bytes

    def widen(self, lmax: int) -> None:
        """Re-pad the resident index plane to a wider Lmax."""
        if int(lmax) > self.lmax:
            self.lmax = int(lmax)
            self.staged["idx"] = jnp.asarray(_pad_idx(self._parts, self.lmax))

    def prefetch(self, start: int, n: int) -> None:
        """No-op: the device gather in ``slab`` is already async."""

    def prefetch_events(self, clients, tag) -> None:
        """No-op: the device gather in ``event_slab`` is already async."""

    def _assemble(self, slots, real):
        sl = jnp.asarray(slots)
        idx = self.staged["idx"][sl]                     # (n, K, Lmax)
        lens = self.staged["len"][sl]
        return {"x": self.staged["x"][idx], "y": self.staged["y"][idx],
                "len": lens, "cid": sl,
                "w": lens.astype(jnp.float32) * jnp.asarray(real)}

    def _assemble_events(self, clients):
        cl = jnp.asarray(clients)
        idx = self.staged["idx"][cl]                     # (E, Lmax)
        return {"x": self.staged["x"][idx], "y": self.staged["y"][idx],
                "len": self.staged["len"][cl]}


class StreamingSlabStager(SlabStager):
    """Slab stager that never stages the population: per-client shards come
    from a host-side factory and only the sampled cohorts' shards are
    gathered (numpy) and copied to device, double-buffered by the inherited
    prefetch thread.

    ``shard_fn(cid) -> (x_c (l, ...), y_c (l,))`` must be deterministic; a
    ``SyntheticPopulation`` generates shards on demand, and
    ``from_partitions`` wraps an in-memory root so streaming can be checked
    bitwise against ``ResidentSlabStager`` on configs that fit.
    """

    def __init__(self, shard_fn, fl, fault, lens, lmax=None):
        super().__init__(fl, fault)
        self._shard = shard_fn
        self.lens = np.asarray(lens, np.int32)
        if len(self.lens) != fl.n_clients:
            raise ValueError(f"{len(self.lens)} shard lengths for "
                             f"n_clients={fl.n_clients}")
        self.lmax = int(lmax) if lmax else max(int(self.lens.max()), 1)
        x0, y0 = shard_fn(0)
        x0, y0 = np.asarray(x0), np.asarray(y0)
        self._item_shape, self._x_dtype = x0.shape[1:], x0.dtype
        self._y_dtype = y0.dtype
        item = int(np.prod(self._item_shape, dtype=np.int64))
        # What full residency would cost: the honest denominator for the
        # bench's staged-bytes ceiling (pad to Lmax like stage_partitions,
        # plus the int32 index/len planes it would carry).
        c = int(fl.n_clients)
        self.resident_bytes = int(
            c * self.lmax * (item * self._x_dtype.itemsize
                             + self._y_dtype.itemsize + 4) + c * 4)
        self.device_bytes = 0

    @classmethod
    def from_partitions(cls, x, y, parts, fl, fault):
        """Streaming view of an in-memory root: shard c is x[parts[c]].

        An empty partition reads root item 0 (mirroring ``_pad_idx``'s
        zero rows) so the assembled slab is byte-identical to the resident
        stager's device gather.
        """
        x, y = np.asarray(x), np.asarray(y)

        def shard(c):
            p = np.asarray(parts[c], np.int64)
            return (x[p], y[p]) if len(p) else (x[:1], y[:1])

        lens = np.asarray([len(p) for p in parts], np.int32)
        st = cls(shard, fl, fault, lens=lens)
        st.data = (x, y, parts)
        return st

    def _padded_shard(self, c):
        xc, yc = self._shard(int(c))
        xc, yc = np.asarray(xc), np.asarray(yc)
        length = max(len(yc), 1)
        reps = -(-self.lmax // length)
        sel = np.concatenate([np.arange(length, dtype=np.int64)] * reps)
        sel = sel[:self.lmax]
        return xc[sel], yc[sel]

    def _assemble(self, slots, real):
        n, k = slots.shape
        sx = np.empty((n, k, self.lmax) + self._item_shape, self._x_dtype)
        sy = np.empty((n, k, self.lmax), self._y_dtype)
        cache = {}
        for i in range(n):
            for j in range(k):
                c = int(slots[i, j])
                if c not in cache:
                    cache[c] = self._padded_shard(c)
                sx[i, j], sy[i, j] = cache[c]
        host = {"x": sx, "y": sy, "len": self.lens[slots],
                "cid": slots, "w": self.lens[slots].astype(np.float32) * real}
        return {key: jnp.asarray(v) for key, v in host.items()}

    def _assemble_events(self, clients):
        e = len(clients)
        sx = np.empty((e, self.lmax) + self._item_shape, self._x_dtype)
        sy = np.empty((e, self.lmax), self._y_dtype)
        cache = {}
        for i, c in enumerate(np.asarray(clients)):
            c = int(c)
            if c not in cache:
                cache[c] = self._padded_shard(c)
            sx[i], sy[i] = cache[c]
        return {"x": jnp.asarray(sx), "y": jnp.asarray(sy),
                "len": jnp.asarray(self.lens[clients])}


class StackedSlabStager(_Prefetcher):
    """Campaign-plane stager: one slab stager per lane, stacked to a leading
    (S,) sweep dim so the vmapped ragged scan consumes it with in_axes=0.

    Lanes are widened to a common Lmax up front; the wider pad is
    unobservable (gather positions stay in [0, len)), so lane ``s`` of the
    stacked slab trains bitwise like the lane's own single run.
    """

    def __init__(self, lanes):
        super().__init__()
        self.lanes = list(lanes)
        self.lmax = max(l.lmax for l in self.lanes)
        for lane in self.lanes:
            lane.widen(self.lmax)
        self.streaming = any(isinstance(l, StreamingSlabStager)
                             for l in self.lanes)
        self.resident_bytes = sum(l.resident_bytes for l in self.lanes)
        self.device_bytes = sum(l.device_bytes for l in self.lanes)

    def slab(self, start: int, n: int):
        """The chunk's stacked (S, n, K, ...) slab on device."""
        return self._take(("sync", start, n),
                          lambda: self._assemble_chunk(start, n))

    def prefetch(self, start: int, n: int) -> None:
        """Background-assemble the next chunk across all streaming lanes."""
        if n > 0 and self.streaming:
            self._submit(("sync", start, n),
                         lambda: self._assemble_chunk(start, n))

    def _assemble_chunk(self, start, n):
        if self.streaming:
            rows = []
            for lane in self.lanes:
                slots, real = lane.plan(start, n)
                rows.append({k: np.asarray(v)
                             for k, v in lane._assemble(slots, real).items()})
            host = {k: np.stack([r[k] for r in rows]) for k in rows[0]}
            return {k: jnp.asarray(v) for k, v in host.items()}
        return jax.tree.map(lambda *ls: jnp.stack(ls),
                            *[lane._assemble_chunk(start, n)
                              for lane in self.lanes])


def make_slab_stager(dataset, fl, fault):
    """Build the right slab stager for a ragged-mode job.

    Datasets exposing the population protocol (a ``shard(cid)`` factory,
    e.g. ``SyntheticPopulation``) are never materialized and require
    ``streaming: true``; in-memory roots stage resident by default and
    stream when asked.
    """
    if hasattr(dataset, "shard"):
        if not fl.streaming:
            raise ValueError(
                f"{type(dataset).__name__} generates shards on demand and "
                "cannot be staged resident — set streaming: true")
        if int(dataset.n_clients) != int(fl.n_clients):
            raise ValueError(f"dataset population ({dataset.n_clients}) != "
                             f"fl.n_clients ({fl.n_clients})")
        lens = np.full(fl.n_clients, int(dataset.items_per_client), np.int32)
        return StreamingSlabStager(dataset.shard, fl, fault, lens=lens)
    x, y, parts = dataset.distribute_into_chunks(
        fl.partition, fl.n_clients, fl.dirichlet_alpha)
    if fl.streaming:
        return StreamingSlabStager.from_partitions(x, y, parts, fl, fault)
    return ResidentSlabStager(x, y, parts, fl, fault)


@dataclasses.dataclass
class SyntheticPopulation:
    """A large client population materialized one shard at a time.

    The streaming-plane exemplar: ``shard(cid)`` deterministically generates
    client ``cid``'s few items from (seed, cid) with the same planted
    class-prototype signal as ``SyntheticVision``, so a 10^5-client
    population costs zero host memory until a cohort is actually sampled.
    """

    n_clients: int = 100_000
    items_per_client: int = 8
    shape: tuple = (8, 8, 1)
    n_classes: int = 10
    seed: int = 0
    noise: float = 0.8

    def __post_init__(self):
        """Lazily-built prototype cache (shared across shards)."""
        self._protos = None

    def shard(self, cid: int):
        """Client ``cid``'s shard as (x (l, ...), y (l,)) numpy arrays."""
        if self._protos is None:
            rng0 = np.random.RandomState(self.seed)
            self._protos = rng0.randn(
                self.n_classes, *self.shape).astype(np.float32)
        rng = np.random.RandomState(
            (1_000_003 * (self.seed + 1) + int(cid)) % (2 ** 31 - 1))
        y = rng.randint(0, self.n_classes, self.items_per_client)
        x = self._protos[y] + self.noise * rng.randn(
            self.items_per_client, *self.shape).astype(np.float32)
        return x.astype(np.float32), y


@dataclasses.dataclass
class SyntheticVision:
    """Deterministic synthetic image classification dataset family."""
    n_items: int = 2048
    shape: tuple = (32, 32, 3)
    n_classes: int = 10
    seed: int = 0
    noise: float = 0.8

    def prepare_root_dataset(self):
        """Generate the root ``(x, y)`` arrays for the configured size."""
        rng = np.random.RandomState(self.seed)
        y = rng.randint(0, self.n_classes, self.n_items)
        protos = rng.randn(self.n_classes, *self.shape).astype(np.float32)
        x = protos[y] + self.noise * rng.randn(
            self.n_items, *self.shape).astype(np.float32)
        return x, y

    def distribute_into_chunks(self, kind: str, n_clients: int,
                               alpha: float = 0.5):
        """Partition the root set; returns ``(x, y, per-client index lists)``."""
        x, y = self.prepare_root_dataset()
        parts = part_mod.partition(kind, y, n_clients, alpha, self.seed)
        return x, y, parts

    @staticmethod
    def client_batches(x, y, idx, batch_size: int, n_steps: int, seed: int,
                       cursor: int = 0):
        """Deterministic batches for one client; returns (batches, cursor)."""
        rng = np.random.RandomState(seed)
        order = idx[rng.permutation(len(idx))]
        reps = int(np.ceil((cursor + n_steps * batch_size) / max(len(order), 1)))
        stream = np.concatenate([order] * max(reps, 1))
        sel = stream[cursor:cursor + n_steps * batch_size]
        sel = sel.reshape(n_steps, batch_size)
        batches = {"x": x[sel], "y": y[sel]}
        return batches, cursor + n_steps * batch_size


@dataclasses.dataclass
class SyntheticLM:
    """Deterministic synthetic next-token LM dataset family."""
    vocab: int = 512
    seed: int = 0

    def tokens(self, batch: int, seq: int, salt: int = 0):
        """Markov-ish token stream: next token depends on previous one."""
        rng = np.random.RandomState(self.seed + salt)
        trans = rng.permutation(self.vocab)
        toks = np.zeros((batch, seq + 1), np.int32)
        toks[:, 0] = rng.randint(0, self.vocab, batch)
        noise = rng.rand(batch, seq)
        rand_tok = rng.randint(0, self.vocab, (batch, seq))
        for t in range(seq):
            nxt = trans[toks[:, t]]
            toks[:, t + 1] = np.where(noise[:, t] < 0.75, nxt, rand_tok[:, t])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def client_batches(self, client_id: int, n_steps: int, batch: int,
                       seq: int, round_idx: int = 0):
        """Return ``n_steps`` stacked token batches for one client-round."""
        out = [self.tokens(batch, seq, salt=client_id * 100003 + round_idx * 7 + s)
               for s in range(n_steps)]
        return {k: np.stack([o[k] for o in out]) for k in out[0]}
