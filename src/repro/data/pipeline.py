"""Deterministic synthetic data pipelines (FLsim Dataset contract).

Two root datasets:
- ``SyntheticVision``: CIFAR-10 / MNIST-shaped classification data with a
  planted linear-signal so models can actually learn (losses decrease and
  accuracies separate across strategies, as the paper's figures need).
- ``SyntheticLM``: token streams with an order-k Markov structure for the
  LM-family architectures.

Every pipeline exposes prepare_root_dataset / distribute_into_chunks /
client_batches with a position cursor, so checkpoints can resume the exact
data order (fault tolerance).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import determinism
from repro.data import partition as part_mod


# ---------------------------------------------------------------------------
# Device-resident staging (the "download once" half of the driver contract)
# ---------------------------------------------------------------------------

def _pad_idx(parts, lmax: int) -> np.ndarray:
    """Ragged per-client index lists -> dense (C, lmax) int32 by cyclic
    repetition. The wrap never biases sampling: gather positions are drawn
    in [0, true len), so pad columns past a client's length are never read —
    which also makes the padding width itself trajectory-invariant."""
    idx = np.zeros((len(parts), lmax), np.int32)
    for c, p in enumerate(parts):
        if len(p):
            reps = int(np.ceil(lmax / len(p)))
            idx[c] = np.concatenate([p] * reps)[:lmax]
    return idx


def stage_partitions(x, y, parts):
    """One-time device staging of the full root dataset + client partitions.

    The ragged per-client index lists are padded to a dense (C, Lmax) int32
    matrix by cyclic repetition (a client with fewer items than the pad just
    wraps; the wrap never biases sampling because the on-device gather draws
    positions modulo the *true* length). Returns a dict of device arrays:

      x    (N, ...)  root features        y    (N,)      root labels
      idx  (C, Lmax) padded item indices  len  (C,)      true partition sizes

    ``len`` doubles as the FedAvg base weight, so zero-item clients get zero
    weight automatically.
    """
    lmax = max(max((len(p) for p in parts), default=1), 1)
    lens = np.asarray([len(p) for p in parts], np.int32)
    return {"x": jnp.asarray(x), "y": jnp.asarray(y),
            "idx": jnp.asarray(_pad_idx(parts, lmax)),
            "len": jnp.asarray(lens)}


def stage_partitions_stacked(trajectories):
    """Stage S trajectories' datasets as one stacked device residency.

    ``trajectories`` is a list of (x, y, parts) triples — one per campaign
    trajectory (different seeds and/or Dirichlet alphas give different root
    data and/or partitions; identical triples are simply duplicated). All
    trajectories must share n_items and n_clients (sweeps vary distribution,
    not problem size). Returns the ``stage_partitions`` dict with a leading
    sweep dim on every leaf:

      x (S, N, ...)   y (S, N)   idx (S, C, Lmax)   len (S, C)

    Lmax is the max over trajectories; because gather positions are drawn in
    [0, len), the wider shared pad is unobservable, so lane ``s`` of the
    stacked gather is bitwise the trajectory's own single staging.
    """
    n_clients = {len(parts) for _, _, parts in trajectories}
    if len(n_clients) != 1:
        raise ValueError(f"trajectories disagree on n_clients: {n_clients}")
    lmax = max(max((max((len(p) for p in parts), default=1), 1)
                   for _, _, parts in trajectories))
    xs = np.stack([np.asarray(x) for x, _, _ in trajectories])
    ys = np.stack([np.asarray(y) for _, y, _ in trajectories])
    idx = np.stack([_pad_idx(parts, lmax) for _, _, parts in trajectories])
    lens = np.stack([np.asarray([len(p) for p in parts], np.int32)
                     for _, _, parts in trajectories])
    return {"x": jnp.asarray(xs), "y": jnp.asarray(ys),
            "idx": jnp.asarray(idx), "len": jnp.asarray(lens)}


# Per-leaf vmap axes for a deduped campaign staging: the concatenated root
# (x, y) is shared across lanes (no sweep axis), only the small per-lane
# index/length planes carry the leading (S,) dim.
DEDUP_STAGED_AXES = {"x": None, "y": None, "idx": 0, "len": 0}


def stage_partitions_dedup(trajectories, keys=None, mesh=None):
    """Stage S trajectories with the shared root datasets deduplicated.

    ``stage_partitions_stacked`` duplicates the root dataset S times even
    when every lane shares it (any scalar-only sweep) — the ROADMAP memory
    item. Here lanes that share a data-plane triple share ONE device copy:
    the unique roots concatenate along the item axis, and each lane's padded
    index matrix is offset into the concatenation, which IS the
    lane->dataset indirection — the gather functions stay untouched and the
    drawn batches are bitwise identical (positions are drawn in
    [0, true len) and the offset just relocates the same bytes). Returns
    ``(staged, lane_ds)``:

      x ((sum_u N_u), ...)  y ((sum_u N_u),)   shared concatenated roots
      idx (S, C, Lmax)      len (S, C)          per-lane (offset) planes

    plus ``lane_ds`` (S,) int32 mapping each lane to its unique dataset (for
    introspection/tests; the indirection itself is baked into ``idx``).
    ``keys`` are optional hashable dedup keys per trajectory (the campaign
    passes its staging-cache keys); identity is the default.

    ``mesh`` (a ``launch/mesh.lane_mesh``) places the staging for a
    device-parallel campaign: the concatenated roots replicate on every
    device, the per-lane ``idx``/``len`` planes shard their leading (S,)
    dim over the ``lanes`` axis — exactly ``DEDUP_STAGED_AXES`` rendered
    as a sharding. S must then be a multiple of the lane count (the
    campaign pads with dead lanes before staging).
    """
    keys = list(keys) if keys is not None else [id(t) for t in trajectories]
    if len(keys) != len(trajectories):
        raise ValueError(f"{len(keys)} dedup keys for "
                         f"{len(trajectories)} trajectories")
    n_clients = {len(parts) for _, _, parts in trajectories}
    if len(n_clients) != 1:
        raise ValueError(f"trajectories disagree on n_clients: {n_clients}")
    uniq: dict = {}
    roots = []
    for k, t in zip(keys, trajectories):
        if k not in uniq:
            uniq[k] = len(roots)
            roots.append(t)
    lane_ds = np.asarray([uniq[k] for k in keys], np.int32)
    lmax = max(max((max((len(p) for p in parts), default=1), 1)
                   for _, _, parts in roots))
    offsets = np.concatenate(
        [[0], np.cumsum([np.asarray(x).shape[0] for x, _, _ in roots])])
    x_cat = np.concatenate([np.asarray(x) for x, _, _ in roots])
    y_cat = np.concatenate([np.asarray(y) for _, y, _ in roots])
    pads = [_pad_idx(parts, lmax) + np.int32(offsets[u])
            for u, (_, _, parts) in enumerate(roots)]
    lens = [np.asarray([len(p) for p in parts], np.int32)
            for _, _, parts in roots]
    staged = {"x": x_cat, "y": y_cat,
              "idx": np.stack([pads[u] for u in lane_ds]),
              "len": np.stack([lens[u] for u in lane_ds])}
    if mesh is not None:
        from repro.launch.mesh import shard_lanes
        staged = shard_lanes(staged, mesh, DEDUP_STAGED_AXES)
    else:
        staged = {k: jnp.asarray(v) for k, v in staged.items()}
    return staged, lane_ds


def gather_one_client_batch(staged, round_key, client, batch_size: int,
                            n_steps: int):
    """Jittable batch gather for a single (possibly traced) client id.

    Positions are drawn uniformly (with replacement) from the client's true
    partition via ``determinism.batch_key(round_key, client)``, so the batch
    stream for a given (seed, round) is identical no matter how rounds (or
    async events) are chunked into launches. The sync driver vmaps this over
    all clients; the async event scan calls it per arriving client — the two
    are bitwise-identical lanes because threefry draws are
    vectorization-invariant. Returns {"x": (n_steps, B, ...), "y": ...}.
    """
    key = determinism.batch_key(round_key, client)
    maxv = jnp.maximum(staged["len"][client], 1)
    pos = jax.random.randint(key, (n_steps, batch_size), 0, maxv)
    sel = staged["idx"][client, pos]
    return {"x": staged["x"][sel], "y": staged["y"][sel]}


def gather_client_batches(staged, round_key, batch_size: int, n_steps: int):
    """Jittable per-round batch gather for every client, on device.

    One vmap over ``gather_one_client_batch`` (the single source of truth
    for the position draw). Returns {"x": (C, n_steps, B, ...), "y": ...}.
    """
    n_clients = staged["idx"].shape[0]
    return jax.vmap(
        lambda c: gather_one_client_batch(staged, round_key, c, batch_size,
                                          n_steps))(jnp.arange(n_clients))


@dataclasses.dataclass
class SyntheticVision:
    n_items: int = 2048
    shape: tuple = (32, 32, 3)
    n_classes: int = 10
    seed: int = 0
    noise: float = 0.8

    def prepare_root_dataset(self):
        rng = np.random.RandomState(self.seed)
        y = rng.randint(0, self.n_classes, self.n_items)
        protos = rng.randn(self.n_classes, *self.shape).astype(np.float32)
        x = protos[y] + self.noise * rng.randn(
            self.n_items, *self.shape).astype(np.float32)
        return x, y

    def distribute_into_chunks(self, kind: str, n_clients: int,
                               alpha: float = 0.5):
        x, y = self.prepare_root_dataset()
        parts = part_mod.partition(kind, y, n_clients, alpha, self.seed)
        return x, y, parts

    @staticmethod
    def client_batches(x, y, idx, batch_size: int, n_steps: int, seed: int,
                       cursor: int = 0):
        """Deterministic batches for one client; returns (batches, cursor)."""
        rng = np.random.RandomState(seed)
        order = idx[rng.permutation(len(idx))]
        reps = int(np.ceil((cursor + n_steps * batch_size) / max(len(order), 1)))
        stream = np.concatenate([order] * max(reps, 1))
        sel = stream[cursor:cursor + n_steps * batch_size]
        sel = sel.reshape(n_steps, batch_size)
        batches = {"x": x[sel], "y": y[sel]}
        return batches, cursor + n_steps * batch_size


@dataclasses.dataclass
class SyntheticLM:
    vocab: int = 512
    seed: int = 0

    def tokens(self, batch: int, seq: int, salt: int = 0):
        """Markov-ish token stream: next token depends on previous one."""
        rng = np.random.RandomState(self.seed + salt)
        trans = rng.permutation(self.vocab)
        toks = np.zeros((batch, seq + 1), np.int32)
        toks[:, 0] = rng.randint(0, self.vocab, batch)
        noise = rng.rand(batch, seq)
        rand_tok = rng.randint(0, self.vocab, (batch, seq))
        for t in range(seq):
            nxt = trans[toks[:, t]]
            toks[:, t + 1] = np.where(noise[:, t] < 0.75, nxt, rand_tok[:, t])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def client_batches(self, client_id: int, n_steps: int, batch: int,
                       seq: int, round_idx: int = 0):
        out = [self.tokens(batch, seq, salt=client_id * 100003 + round_idx * 7 + s)
               for s in range(n_steps)]
        return {k: np.stack([o[k] for o in out]) for k in out[0]}
