"""Dataset distribution (paper component 3: Dataset Distributor).

Implements FLsim's ``distribute_into_chunks`` contract: deterministic
partition of a root dataset into per-client chunks under
- ``dirichlet`` — label-Dirichlet(alpha) non-IID (the paper's experiments use
  alpha = 0.5 on CIFAR-10),
- ``iid``       — uniform shuffle-split,
- ``shards``    — sort-by-label shard assignment (McMahan-style pathological
  non-IID).
"""
from __future__ import annotations

import numpy as np


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float,
                        seed: int = 0, min_size: int = 2,
                        max_retries: int = 100):
    """Returns list of index arrays, one per client.

    Draws are resampled until every client holds ``min_size`` items, bounded
    by ``max_retries`` (each retry forks the RNG forward, so retry r of one
    call equals retry r of any other call with the same seed). A tiny alpha
    with many clients concentrates nearly all mass on a few clients, which
    used to hang forever here — now it raises with the offending settings.
    """
    rng = np.random.RandomState(seed)
    n_classes = int(labels.max()) + 1
    for _ in range(max_retries):
        idx_by_client = [[] for _ in range(n_clients)]
        for c in range(n_classes):
            idx_c = np.where(labels == c)[0]
            rng.shuffle(idx_c)
            props = rng.dirichlet(np.repeat(alpha, n_clients))
            cuts = (np.cumsum(props) * len(idx_c)).astype(int)[:-1]
            for i, part in enumerate(np.split(idx_c, cuts)):
                idx_by_client[i].extend(part.tolist())
        sizes = [len(ix) for ix in idx_by_client]
        if min(sizes) >= min_size:
            return [np.array(sorted(ix)) for ix in idx_by_client]
    raise ValueError(
        f"dirichlet_partition: no draw gave every client >= {min_size} "
        f"items after {max_retries} retries (alpha={alpha}, "
        f"n_clients={n_clients}, n_items={len(labels)}); raise alpha, "
        "lower n_clients/min_size, or add data")


def iid_partition(n_items: int, n_clients: int, seed: int = 0):
    """Shuffle items uniformly into ``n_clients`` equal shards."""
    rng = np.random.RandomState(seed)
    perm = rng.permutation(n_items)
    return [np.sort(p) for p in np.array_split(perm, n_clients)]


def shard_partition(labels: np.ndarray, n_clients: int,
                    shards_per_client: int = 2, seed: int = 0):
    """Sort-by-label shard partition (pathological non-IID)."""
    rng = np.random.RandomState(seed)
    order = np.argsort(labels, kind="stable")
    shards = np.array_split(order, n_clients * shards_per_client)
    assign = rng.permutation(len(shards))
    out = []
    for i in range(n_clients):
        ids = np.concatenate([shards[assign[i * shards_per_client + j]]
                              for j in range(shards_per_client)])
        out.append(np.sort(ids))
    return out


def partition(kind: str, labels: np.ndarray, n_clients: int,
              alpha: float = 0.5, seed: int = 0):
    """Dispatch to a partitioner by name (``iid`` | ``dirichlet`` | ``shards``)."""
    if kind == "dirichlet":
        return dirichlet_partition(labels, n_clients, alpha, seed)
    if kind == "iid":
        return iid_partition(len(labels), n_clients, seed)
    if kind == "shards":
        return shard_partition(labels, n_clients, seed=seed)
    raise KeyError(kind)


def heterogeneity(parts, labels: np.ndarray) -> float:
    """Mean total-variation distance of client label dists vs global —
    0 = IID; grows as alpha shrinks. Used by tests/benches."""
    n_classes = int(labels.max()) + 1
    glob = np.bincount(labels, minlength=n_classes) / len(labels)
    tvs = []
    for ix in parts:
        if len(ix) == 0:
            continue
        loc = np.bincount(labels[ix], minlength=n_classes) / len(ix)
        tvs.append(0.5 * np.abs(loc - glob).sum())
    return float(np.mean(tvs))
