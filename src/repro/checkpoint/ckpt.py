"""Round-granular checkpointing with elastic restore (fault tolerance).

Layout: <dir>/round_<n>/
  manifest.json  — round, rng, data cursors, tree structure, mesh shape
  shard_<k>.npz  — parameter/optimizer leaves (per-host shard in a real
                   deployment; single archive here)

restore() reshards to whatever mesh/placement the *new* process uses
(elastic scale up/down): leaves are saved as full logical arrays, so loading
under a different device count just re-applies the new shardings.

Async save: the arrays are snapshotted (device_get) synchronously — cheap
relative to a round — and written by a worker thread so training continues.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir, round_idx: int, state, extra: Optional[dict] = None,
         async_write: bool = True, keep_last: int = 3):
    """state: pytree of arrays. Returns the checkpoint path."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    path = ckpt_dir / f"round_{round_idx:08d}"
    tmp = ckpt_dir / f".tmp_round_{round_idx:08d}"
    leaves, treedef = _flatten(state)
    host_leaves = [np.asarray(l) for l in leaves]   # snapshot now

    def write():
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "shard_0.npz",
                 **{f"leaf_{i}": l for i, l in enumerate(host_leaves)})
        manifest = {
            "round": round_idx,
            "n_leaves": len(host_leaves),
            "treedef": str(treedef),
            "extra": extra or {},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if path.exists():
            shutil.rmtree(path)
        tmp.rename(path)                             # atomic publish
        _gc(ckpt_dir, keep_last)

    if async_write:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return path, t
    write()
    return path, None


def _gc(ckpt_dir: pathlib.Path, keep_last: int):
    rounds = sorted(p for p in ckpt_dir.glob("round_*") if p.is_dir())
    for p in rounds[:-keep_last]:
        shutil.rmtree(p, ignore_errors=True)


def latest_round(ckpt_dir) -> Optional[int]:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    rounds = sorted(ckpt_dir.glob("round_*"))
    if not rounds:
        return None
    return int(rounds[-1].name.split("_")[1])


def restore(ckpt_dir, round_idx: int, like_state, shardings=None):
    """Load into the structure of ``like_state``; apply ``shardings`` (a
    matching pytree of jax.sharding.Sharding) for elastic resharding."""
    path = pathlib.Path(ckpt_dir) / f"round_{round_idx:08d}"
    manifest = json.loads((path / "manifest.json").read_text())
    with np.load(path / "shard_0.npz") as z:
        host = [z[f"leaf_{i}"] for i in range(manifest["n_leaves"])]
    leaves, treedef = _flatten(like_state)
    assert len(leaves) == len(host), \
        f"checkpoint has {len(host)} leaves, state needs {len(leaves)}"
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_flatten(shardings)[0]
        out = [jax.device_put(h, s) for h, s in zip(host, sh_leaves)]
    else:
        out = [jax.numpy.asarray(h) for h in host]
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]
