"""Performance Logger + FL-Dashboard (paper component 6).

Collects per-round model metrics and host resource usage into JSONL + CSV;
``dashboard()`` renders the terminal summary the paper's web dashboard shows.
"""
from __future__ import annotations

import csv
import json
import pathlib
import resource
import sys
import time
from typing import Optional


def _rss_mb(ru_maxrss: int) -> float:
    """``ru_maxrss`` -> MB. getrusage reports kilobytes on Linux but BYTES
    on macOS (see getrusage(2) on each) — without normalizing, Darwin
    dashboards read 1024x too large."""
    return ru_maxrss / (2**20 if sys.platform == "darwin" else 1024)


def host_usage() -> dict:
    """Host resource snapshot (CPU seconds + peak RSS, platform-normalized)
    — shared by the per-round logger rows and the flight recorder's
    per-launch host counters so the two can never disagree on units."""
    usage = resource.getrusage(resource.RUSAGE_SELF)
    return {"cpu_s": round(usage.ru_utime + usage.ru_stime, 3),
            "max_rss_mb": round(_rss_mb(usage.ru_maxrss), 1)}


class PerformanceLogger:
    def __init__(self, out_dir=None, run_name: str = "run"):
        self.rows = []
        self.run_name = run_name
        self.out_dir = pathlib.Path(out_dir) if out_dir else None
        self._t0 = time.time()
        if self.out_dir:
            self.out_dir.mkdir(parents=True, exist_ok=True)

    def log_round(self, round_idx: int, **metrics):
        row = {
            "round": round_idx,
            "wall_s": round(time.time() - self._t0, 3),
            **host_usage(),
            **{k: (float(v) if hasattr(v, "__float__") else v)
               for k, v in metrics.items()},
        }
        self.rows.append(row)
        if self.out_dir:
            with open(self.out_dir / f"{self.run_name}.jsonl", "a") as f:
                f.write(json.dumps(row) + "\n")
        return row

    def to_csv(self, path=None):
        if path is None:
            if self.out_dir is None:
                raise ValueError(
                    "PerformanceLogger.to_csv needs an explicit path when "
                    "the logger was constructed with out_dir=None")
            path = self.out_dir / f"{self.run_name}.csv"
        keys = sorted({k for r in self.rows for k in r})
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=keys)
            w.writeheader()
            w.writerows(self.rows)
        return path

    def series(self, key: str):
        return [r.get(key) for r in self.rows]

    def dashboard(self) -> str:
        if not self.rows:
            return "(no rounds logged)"
        keys = [k for k in self.rows[-1] if k not in ("round",)]
        lines = [f"== FL dashboard: {self.run_name} "
                 f"({len(self.rows)} rounds) =="]
        last = self.rows[-1]
        for k in keys:
            vals = [r.get(k) for r in self.rows if isinstance(r.get(k), (int, float))]
            if vals and isinstance(last.get(k), (int, float)):
                lines.append(f"  {k:>14}: last={last[k]:.4f} "
                             f"min={min(vals):.4f} max={max(vals):.4f}")
        return "\n".join(lines)
