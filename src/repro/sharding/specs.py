"""PartitionSpec rule tables for every (architecture x phase).

Phases:
- ``fsdp``    (train / prefill): every block tensor ZeRO-3-sharded over
  ``model`` on one divisible dim and all-gathered per layer inside the layer
  scan. MoE expert tensors are EP-resident (never gathered): see moe.py.
- ``tp``      (decode): column/row tensor-parallel resident weights; tensors
  whose parallel dim does not divide the mesh (MLA attention, xLSTM) are
  replicated — they are small by construction.
- ``spatial`` (small archs): everything replicated; the flattened
  (data x model) grid is the FL client grid.

Rules are keyed by parameter leaf name; the table is validated by
tests/test_sharding_specs.py (every spec dim must divide the mesh axis).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import transformer

# Archs small enough for spatial (per-chip replica) placement.
SPATIAL_ARCHS = ("whisper-base", "xlstm-125m", "flsim-cnn", "flsim-mlp",
                 "flsim-logreg")


def placement_for(cfg: ModelConfig) -> str:
    name = cfg.name.removesuffix("-reduced")
    return "spatial" if name in SPATIAL_ARCHS else "temporal"


# ---------------------------------------------------------------------------
# Rule tables: name -> dim sharded over `model` (per-layer shapes, no stack
# dim). None = replicated.
# ---------------------------------------------------------------------------

_FSDP_DIM = {
    # attention (GQA)
    "wq": 1, "wk": 1, "wv": 1, "wo": 0, "bq": 0, "bk": 0, "bv": 0,
    "q_norm": 0, "k_norm": 0,
    # MLA
    "wdq": 1, "wuq": 1, "wdkv": 1, "kv_norm": 0, "wukv": 1,
    # MLP (w1/w3/w2 shared with experts-free path)
    "w1": 1, "w3": 1, "w2": 0, "b1": 0, "b2": 0,
    # norms
    "w": 0, "b": 0,
    # moe (router gathered; experts resident -> handled separately)
    "router": 1,
    # mamba
    "in_proj_x": 1, "in_proj_z": 1, "conv_w": 1, "conv_b": 0,
    "x_proj": 1, "dt_proj": 1, "dt_bias": 0, "A_log": 0, "D_skip": 0,
    "out_proj": 0,
    # xlstm
    "up_proj": 1, "wif": 0, "o_norm": 0, "down_proj": 0,
    "wx": 1, "rh": 1, "ff1": 1, "ff2": 0,
}

_TP_DIM = {
    # attention: column for qkv (flattened head dim divides), row for wo
    "wq": 1, "wk": 1, "wv": 1, "wo": 0, "bq": 0, "bk": 0, "bv": 0,
    "q_norm": None, "k_norm": None,
    # MLA decode: replicated (absorbed einsums are not head-shardable)
    "wdq": None, "wuq": None, "wdkv": None, "kv_norm": None, "wukv": None,
    # MLP
    "w1": 1, "w3": 1, "w2": 0, "b1": 0, "b2": None,
    "w": None, "b": None,
    "router": None,
    # mamba decode: channels (d_inner) sharded
    "in_proj_x": 1, "in_proj_z": 1, "conv_w": 1, "conv_b": 0,
    "x_proj": 0, "dt_proj": 1, "dt_bias": 0, "A_log": 0, "D_skip": 0,
    "out_proj": 0,
    # xlstm decode: replicated (tiny)
    "up_proj": None, "wif": None, "o_norm": None, "down_proj": None,
    "wx": None, "rh": None, "ff1": None, "ff2": None,
}

# MLA attention weights replicate in tp mode; wo for MLA too.
_TP_MLA_OVERRIDE = {"wo": None, "wq": None, "wk": None, "wv": None}


def _moe_expert_spec(cfg: ModelConfig, nstack: int) -> dict:
    """Expert tensors (stack, E, D, F)/(stack, E, F, D): EP-resident."""
    if cfg.moe.ep_mode == "model":
        w1 = P(*((None,) * nstack), "model", None, None)
        w2 = P(*((None,) * nstack), "model", None, None)
    elif cfg.moe.ep_mode == "subgrid":
        # packed (E*f_sub, D, F/f_sub) over the flattened grid
        w1 = P(*((None,) * nstack), ("data", "model"), None, None)
        w2 = w1
    else:  # grid: E over data, F over model
        w1 = P(*((None,) * nstack), "data", None, "model")
        w2 = P(*((None,) * nstack), "data", "model", None)
    return {"w1": w1, "w3": w1, "w2": w2}


def param_specs(cfg: ModelConfig, phase: str) -> dict:
    """PartitionSpec tree matching transformer.param_shapes(cfg) exactly."""
    shapes = transformer.param_shapes(cfg)
    if phase == "spatial":
        return jax.tree.map(lambda sh: P(), shapes,
                            is_leaf=lambda x: isinstance(x, tuple))
    table = dict(_TP_DIM if phase == "tp" else _FSDP_DIM)
    if phase == "tp" and cfg.attn_type == "mla":
        table.update(_TP_MLA_OVERRIDE)

    def assign(path, shape):
        keys = [k.key for k in path if hasattr(k, "key")]
        name = keys[-1]
        top = keys[0]
        # input embedding: D-sharded (local lookup + tiny feature gather);
        # tied embeddings stay vocab-sharded (shared with the head).
        if name == "embed":
            if cfg.tie_embeddings:
                return P("model", None)
            return P(None, "model")
        if name == "lm_head":
            return P(None, "model")
        if top in ("final_norm", "enc_final_norm"):
            return P(None)
        nstack = len(shape) - _base_ndim(cfg, keys)
        # MoE experts: EP-resident
        if "moe" in keys and name in ("w1", "w3", "w2"):
            return _moe_expert_spec(cfg, nstack)[name]
        dim = table.get(name, 0 if len(shape) == 1 else None)
        if dim is None:
            return P(*([None] * len(shape)))
        dim += nstack
        if shape[dim] % 16 != 0:
            # fall back to replication if the mesh cannot divide this dim
            return P(*([None] * len(shape)))
        spec = [None] * len(shape)
        spec[dim] = "model"
        return P(*spec)

    flat = jax.tree_util.tree_flatten_with_path(
        shapes, is_leaf=lambda x: isinstance(x, tuple))
    leaves = [assign(path, sh) for path, sh in flat[0]]
    return jax.tree.unflatten(flat[1], leaves)


def _base_ndim(cfg: ModelConfig, keys) -> int:
    """ndim of the per-layer tensor (no stack dims) for this leaf."""
    name = keys[-1]
    base = {
        "wq": 2, "wk": 2, "wv": 2, "wo": 2, "bq": 1, "bk": 1, "bv": 1,
        "q_norm": 1, "k_norm": 1, "wdq": 2, "wuq": 2, "wdkv": 2,
        "kv_norm": 1, "wukv": 2, "w1": 2, "w3": 2, "w2": 2, "b1": 1, "b2": 1,
        "w": 1, "b": 1, "router": 2, "in_proj_x": 2, "in_proj_z": 2,
        "conv_w": 2, "conv_b": 1, "x_proj": 2, "dt_proj": 2, "dt_bias": 1,
        "A_log": 2, "D_skip": 1, "out_proj": 2, "up_proj": 2, "wif": 2,
        "o_norm": 1, "down_proj": 2, "wx": 2, "rh": 2, "ff1": 2, "ff2": 2,
        "embed": 2, "lm_head": 2,
    }[name]
    if "moe" in keys and name in ("w1", "w3", "w2"):
        base = 3  # (E, D, F)
    return base


def gather_dim_table(cfg: ModelConfig) -> dict:
    """(parent, name) -> per-scan-body gather dim over ``model``, or None.

    The layer scan consumes exactly ONE leading stack dim, so the gather dim
    is the storage-spec 'model' position minus one — correct for nested
    stacks too (jamba period tensors keep their inner (7,)/(4,) dims inside
    the scan body). None = never gathered (EP experts, vocab shards,
    replicated leaves)."""
    specs = param_specs(cfg, "fsdp")
    shapes = transformer.param_shapes(cfg)
    table: dict = {}

    def visit(path, spec):
        keys = [k.key for k in path if hasattr(k, "key")]
        name = keys[-1]
        parent = keys[-2] if len(keys) >= 2 else ""
        top = keys[0]
        if top in ("embed", "lm_head", "final_norm", "enc_final_norm"):
            return
        if "moe" in keys and name in ("w1", "w3", "w2"):
            table[(parent, name)] = None
            return
        dim = None
        for i, entry in enumerate(spec):
            if entry == "model" or (isinstance(entry, tuple)
                                    and "model" in entry):
                dim = i - 1
                break
        prev = table.get((parent, name), "missing")
        assert prev in ("missing", dim), \
            f"gather-dim conflict for {(parent, name)}: {prev} vs {dim}"
        table[(parent, name)] = dim

    flat_sh = jax.tree_util.tree_flatten_with_path(
        shapes, is_leaf=lambda x: isinstance(x, tuple))
    flat_sp = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for (path, _), sp in zip(flat_sh[0], flat_sp):
        visit(path, sp)
    return table


def make_gather_fn(cfg: ModelConfig, ctx):
    """Closure for the per-layer ZeRO-3 all-gather used by the layer scans.
    Works on any block subtree (decoder, encoder, hybrid period).

    REPRO_QUANT_GATHER=1 (beyond-paper, EXPERIMENTS.md §Perf): big weight
    shards are symmetric-int8 block-quantized before the gather and
    dequantized after — the paper's communication-efficient-FL idea applied
    to the intra-model ZeRO-3 collectives. Halves AG bytes vs bf16
    (W8A16-style compute; the fp master copy is untouched)."""
    import os
    if ctx.model is None or placement_for(cfg) == "spatial":
        return lambda blk: blk
    table = gather_dim_table(cfg)
    quant = os.environ.get("REPRO_QUANT_GATHER") == "1"

    def ag(t, d):
        import jax.numpy as jnp
        if quant and t.size >= 1 << 16 and t.dtype == jnp.bfloat16:
            amax = jnp.max(jnp.abs(t.astype(jnp.float32)),
                           axis=d, keepdims=True)
            scale = jnp.where(amax > 0, amax / 127.0, 1.0)
            q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale),
                         -127, 127).astype(jnp.int8)
            qg = jax.lax.all_gather(q, ctx.model, axis=d, tiled=True)
            sg = jax.lax.all_gather(scale, ctx.model, axis=d, tiled=True)
            # dequant: shard j of the tiled gather uses scale slice j
            M = sg.shape[d]
            loc = qg.shape[d] // M
            qm = jnp.moveaxis(qg, d, -1)
            sm = jnp.moveaxis(sg, d, -1)
            out = (qm.reshape(qm.shape[:-1] + (M, loc)).astype(jnp.float32)
                   * sm[..., None]).reshape(qm.shape)
            return jnp.moveaxis(out, -1, d).astype(t.dtype)
        return jax.lax.all_gather(t, ctx.model, axis=d, tiled=True)

    def gather(blk_loc):
        def f(path, t):
            keys = [k.key for k in path if hasattr(k, "key")]
            name = keys[-1]
            parent = keys[-2] if len(keys) >= 2 else ""
            d = table.get((parent, name))
            if d is None:
                return t
            return ag(t, d)
        return jax.tree_util.tree_map_with_path(f, blk_loc)

    return gather


def make_grad_sync(cfg: ModelConfig, ctx):
    """Spec-aware gradient sync for the temporal round: pmean over the batch
    axes (pod, data) for every leaf NOT sharded over them (grid-EP expert
    grads are data-local by construction — their tokens arrived via a2a)."""
    if ctx.pod is None and ctx.data is None:
        return lambda g: g        # meshless (CPU-scale) path: nothing to sync
    specs = param_specs(cfg, "fsdp")

    def sync(grads):
        def f(g, sp):
            axes = []
            for a in (ctx.pod, ctx.data):
                if a is None:
                    continue
                in_spec = any(
                    a in (e if isinstance(e, tuple) else (e,))
                    for e in sp if e is not None)
                if not in_spec:
                    axes.append(a)
            return jax.lax.pmean(g, tuple(axes)) if axes else g

        return jax.tree.map(f, grads, specs,
                            is_leaf=lambda x: isinstance(x, P))

    return sync


def batch_specs(cfg: ModelConfig, shape_kind: str, global_batch: int,
                mesh_axes) -> P:
    """Sharding of the leading batch dim for a given phase/mesh.

    Batch goes over (pod, data) when divisible; decode long-context (B=1)
    replicates. Spatial archs shard the client grid over (data, model)."""
    axes = []
    n = 1
    sizes = dict(mesh_axes)
    if placement_for(cfg) == "spatial" and shape_kind == "train":
        want = ["data", "model"]
    elif shape_kind in ("train", "prefill"):
        want = ["pod", "data"]
    else:  # decode
        want = ["pod", "data"]
    for a in want:
        if a in sizes and global_batch % (n * sizes[a]) == 0:
            axes.append(a)
            n *= sizes[a]
    return P(tuple(axes) if axes else None)
