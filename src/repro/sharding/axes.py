"""Mesh-axis context: collectives that degrade to no-ops off-mesh.

Model code is written once against an ``AxisCtx``. With ``AxisCtx()`` (all axes
None) every collective is the identity and the code runs on one device — that
is the oracle used by tests. Inside ``shard_map`` over the production mesh the
same code emits real collectives.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


_FOLLOW_MODEL = "__follow_model__"


@dataclass(frozen=True)
class AxisCtx:
    data: Optional[str] = None    # FL-client / batch axis
    model: Optional[str] = None   # TP / FSDP / EP axis
    pod: Optional[str] = None     # hierarchical / replica axis
    # vocab-sharding axis for embeddings/logits/loss; defaults to `model`.
    # Spatial archs keep full (replicated) embeddings while still using the
    # model axis for sequence-sharded caches — there vocab=None.
    vocab: Optional[str] = _FOLLOW_MODEL

    @property
    def vaxis(self) -> Optional[str]:
        return self.model if self.vocab == _FOLLOW_MODEL else self.vocab

    # -- axis sizes (1 when absent) -----------------------------------
    def size(self, name: Optional[str]) -> int:
        if name is None:
            return 1
        if hasattr(jax.lax, "axis_size"):
            return jax.lax.axis_size(name)
        return jax.core.axis_frame(name)         # older jax: returns the size

    def index(self, name: Optional[str]):
        if name is None:
            return 0
        return jax.lax.axis_index(name)

    @property
    def data_axes(self):
        """Axes that jointly act as the batch/client grid (data [+ pod])."""
        axes = tuple(a for a in (self.pod, self.data) if a is not None)
        return axes if axes else None

    # -- collectives ---------------------------------------------------
    def all_gather(self, x, name: Optional[str], axis: int):
        if name is None:
            return x
        return jax.lax.all_gather(x, name, axis=axis, tiled=True)

    def psum(self, x, name):
        if name is None or (isinstance(name, tuple) and not name):
            return x
        return jax.lax.psum(x, name)

    def pmean(self, x, name):
        if name is None or (isinstance(name, tuple) and not name):
            return x
        return jax.lax.pmean(x, name)

    def psum_scatter(self, x, name: Optional[str], axis: int):
        if name is None:
            return x
        return jax.lax.psum_scatter(x, name, scatter_dimension=axis, tiled=True)

    def all_to_all(self, x, name: Optional[str], split_axis: int, concat_axis: int):
        if name is None:
            return x
        return jax.lax.all_to_all(x, name, split_axis=split_axis,
                                  concat_axis=concat_axis, tiled=True)

    def ppermute(self, x, name: Optional[str], perm):
        if name is None:
            return x
        return jax.lax.ppermute(x, name, perm=perm)


# Convenience contexts
SINGLE = AxisCtx()


def gather_on_spec(ctx: AxisCtx, tensor: jnp.ndarray, spec, axis_name: str):
    """All-gather ``tensor`` along whichever dim ``spec`` shards over ``axis_name``.

    ``spec`` is a PartitionSpec-like tuple; entries may be None, a name, or a
    tuple of names. Returns the tensor with that dim unsharded.
    """
    if axis_name is None:
        return tensor
    for dim, entry in enumerate(spec):
        names = entry if isinstance(entry, tuple) else (entry,)
        if axis_name in names:
            return ctx.all_gather(tensor, axis_name, axis=dim)
    return tensor


def gather_params(ctx: AxisCtx, params, specs, axis_name: str):
    """ZeRO-3 style: all-gather every tensor on its ``axis_name``-sharded dim."""
    return jax.tree.map(
        lambda t, s: gather_on_spec(ctx, t, s, axis_name), params, specs,
        is_leaf=lambda x: x is None)
