"""Pallas TPU fused RMSNorm kernel (rows tiled, f32 accumulation)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)            # (BR, D)
    w = w_ref[...].astype(jnp.float32)            # (1, D)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, w, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = False):
    """x: (..., D); w: (D,). Rows are tiled ``block_rows`` at a time."""
    shape = x.shape
    D = shape[-1]
    xf = x.reshape(-1, D)
    R = xf.shape[0]
    block_rows = min(block_rows, R)
    pad = (-R) % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    Rp = xf.shape[0]

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(Rp // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, D), x.dtype),
        interpret=interpret,
    )(xf, w.reshape(1, D))
    if pad:
        out = out[:R]
    return out.reshape(shape)
