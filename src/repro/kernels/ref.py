"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth for the interpret-mode kernel tests and the
numerically-stable reference used by small-shape unit tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal: bool = True, q_offset=0,
                        scale: float | None = None):
    """Plain softmax attention.

    q: (B, Sq, H, Dk); k: (B, Sk, KV, Dk); v: (B, Sk, KV, Dv) with H % KV == 0.
    Positions of q are ``q_offset + arange(Sq)`` for causal masking.
    Returns (B, Sq, H, Dv).
    """
    B, Sq, H, Dk = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / np.sqrt(Dk)
    qg = q.reshape(B, Sq, KV, G, Dk)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * scale
    if causal:
        qpos = q_offset + jnp.arange(Sq)
        kpos = jnp.arange(k.shape[1])
        mask = qpos[:, None] >= kpos[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
    return o.reshape(B, Sq, H, v.shape[-1])


def decode_attention_ref(q, k, v, length, *, scale: float | None = None,
                         return_stats: bool = False):
    """Single-token attention over a (possibly partially filled) KV cache.

    q: (B, H, Dk); k: (B, S, KV, Dk); v: (B, S, KV, Dv); length: (B,) valid
    prefix lengths. Returns (B, H, Dv) (plus (m, l) row stats if requested —
    used for cross-shard log-sum-exp combination).
    """
    B, H, Dk = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / np.sqrt(Dk)
    qg = q.reshape(B, KV, G, Dk)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k).astype(jnp.float32) * scale
    valid = jnp.arange(k.shape[1])[None] < length[:, None]        # (B, S)
    s = jnp.where(valid[:, None, None], s, -1e30)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v.dtype), v)
    o = (o.astype(jnp.float32) / jnp.maximum(l, 1e-30)[..., None])
    o = o.reshape(B, H, v.shape[-1])
    if return_stats:
        return o, m.reshape(B, H), l.reshape(B, H)
    return o


def rmsnorm_ref(x, w, eps: float = 1e-6):
    """RMSNorm over the last dim; f32 accumulation."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def quant_aggregate_ref(qdeltas, scales, weights):
    """Dequantize int8 client deltas and reduce with client weights.

    qdeltas: (C, N) int8; scales: (C, N // block) f32 per-block scales;
    weights: (C,) f32 normalized client weights. Returns (N,) f32:
    ``sum_c weights[c] * qdeltas[c] * scales[c, block(n)]``.
    """
    C, N = qdeltas.shape
    nblocks = scales.shape[1]
    block = N // nblocks
    d = qdeltas.astype(jnp.float32).reshape(C, nblocks, block)
    d = d * scales[..., None]
    return jnp.einsum("c,cnb->nb", weights, d).reshape(N)


def quantize_blockwise_ref(x, block: int = 256):
    """Symmetric int8 block quantization. x: (N,) -> (int8 (N,), scales (N/block,))."""
    N = x.shape[0]
    nblocks = N // block
    xb = x.reshape(nblocks, block)
    amax = jnp.max(jnp.abs(xb), axis=1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xb / scale[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(N), scale.astype(jnp.float32)
