"""Pallas TPU decode-attention kernel: one query token over a long KV cache.

Decode attention is HBM-bandwidth-bound: the whole KV cache streams through
VMEM once per step. The kernel therefore:
- processes one (batch, kv-head) pair per grid row with the whole GQA query
  group (G = H // KV queries) resident in VMEM — the cache is read ONCE for
  the group rather than once per query head;
- iterates kv blocks on the sequential trailing grid dim with the online
  softmax accumulator in VMEM scratch;
- masks by the per-sequence valid ``length`` (partially filled caches).

Emits (o, m, l) so callers can log-sum-exp-combine partial results across a
sequence-sharded cache (chunk-parallel decode; see models/attention.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
STATS_LANES = 128


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   m_scr, l_scr, acc_scr, *, scale: float, block_k: int):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0]
    k_start = ki * block_k

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                      # (G, Dk)
        k = k_ref[0].astype(jnp.float32)                      # (bk, Dk)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale        # (G, bk)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_scr[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[:, 0] = l_scr[:, 0] * alpha + p.sum(axis=1)
        m_scr[:, 0] = m_new
        v = v_ref[0].astype(jnp.float32)                      # (bk, Dv)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finalize():
        # Unnormalized output + stats; caller divides (possibly after a
        # cross-shard combine).
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)
        m_ref[0] = m_scr[:, :1].astype(m_ref.dtype)
        l_ref[0] = l_scr[:, :1].astype(l_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "block_k", "interpret"))
def decode_attention_fwd(q, k, v, length, *, scale: float | None = None,
                         block_k: int = 512, interpret: bool = False):
    """q: (B, H, Dk); k: (B, S, KV, Dk); v: (B, S, KV, Dv); length: (B,) int32.

    Returns unnormalized (o: (B, H, Dv) f32, m: (B, H) f32, l: (B, H) f32)
    where ``softmax_output = o / l`` — kept separate for LSE-combines.
    """
    B, H, Dk = q.shape
    _, S, KV, Dv = v.shape
    G = H // KV
    scale = float(scale if scale is not None else 1.0 / np.sqrt(Dk))
    block_k = min(block_k, S)
    assert S % block_k == 0

    qf = q.reshape(B * KV, G, Dk)
    kf = jnp.moveaxis(k, 2, 1).reshape(B * KV, S, Dk)
    vf = jnp.moveaxis(v, 2, 1).reshape(B * KV, S, Dv)
    lengths = jnp.broadcast_to(length[:, None], (B, KV)).reshape(B * KV)

    grid = (B * KV, S // block_k)

    o, m, l = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bk, ki: (bk,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, G, Dk), lambda bk, ki: (bk, 0, 0)),
            pl.BlockSpec((1, block_k, Dk), lambda bk, ki: (bk, ki, 0)),
            pl.BlockSpec((1, block_k, Dv), lambda bk, ki: (bk, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, G, Dv), lambda bk, ki: (bk, 0, 0)),
            pl.BlockSpec((1, G, 1), lambda bk, ki: (bk, 0, 0)),
            pl.BlockSpec((1, G, 1), lambda bk, ki: (bk, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * KV, G, Dv), jnp.float32),
            jax.ShapeDtypeStruct((B * KV, G, 1), jnp.float32),
            jax.ShapeDtypeStruct((B * KV, G, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, STATS_LANES), jnp.float32),
            pltpu.VMEM((G, STATS_LANES), jnp.float32),
            pltpu.VMEM((G, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, qf, kf, vf)
    return (o.reshape(B, H, Dv), m.reshape(B, H), l.reshape(B, H))
