"""Pallas TPU flash-attention forward kernel (causal / full, GQA, MLA dims).

TPU adaptation notes (vs the CUDA flash-attention the literature describes):
- tiling is chosen for VMEM residency and MXU alignment: q/k tiles are
  (block_q x Dk) / (block_k x Dk) with block sizes multiples of 128 (lane dim)
  and 8 (sublane dim);
- the kv loop is the innermost *sequential* grid dimension — TPU grids execute
  the trailing dimension in order on a core, so the online-softmax accumulator
  lives in VMEM scratch across kv steps (no atomics / shared-memory banking);
- GQA is handled by an index map (q head h reads kv head h // group) rather
  than materializing repeated KV.

Supports Dk != Dv (MLA uses qk dim 96, v dim 64 — both padded to 128 lanes by
the wrapper when needed).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
STATS_LANES = 128  # m/l scratch uses a full lane register row per q row


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                causal: bool, scale: float, block_q: int, block_k: int,
                q_offset: int):
    """One (batch*head, q_block, kv_block) grid step."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = q_offset + qi * block_q
    k_start = ki * block_k

    def compute():
        q = q_ref[0].astype(jnp.float32)                    # (bq, Dk)
        k = k_ref[0].astype(jnp.float32)                    # (bk, Dk)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # (bq, bk)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[:, 0] = l_scr[:, 0] * alpha + p.sum(axis=1)
        m_scr[:, 0] = m_new
        v = v_ref[0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    if causal:
        # Skip kv blocks strictly above the diagonal (block-level early exit).
        pl.when(k_start <= q_start + block_q - 1)(compute)
    else:
        compute()

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[:, 0], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "q_offset",
                     "interpret"))
def flash_attention_fwd(q, k, v, *, causal: bool = True,
                        scale: float | None = None, block_q: int = 512,
                        block_k: int = 512, q_offset: int = 0,
                        interpret: bool = False):
    """q: (B, Sq, H, Dk); k: (B, Sk, KV, Dk); v: (B, Sk, KV, Dv) -> (B, Sq, H, Dv).

    ``q_offset`` is the global position of q row 0 (static; used when the
    caller shards the query sequence).
    """
    B, Sq, H, Dk = q.shape
    _, Sk, KV, Dv = v.shape
    G = H // KV
    scale = float(scale if scale is not None else 1.0 / np.sqrt(Dk))
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0

    # Layouts: q (B*H, Sq, Dk); k/v (B*KV, Sk, D*)
    qf = jnp.moveaxis(q, 2, 1).reshape(B * H, Sq, Dk)
    kf = jnp.moveaxis(k, 2, 1).reshape(B * KV, Sk, Dk)
    vf = jnp.moveaxis(v, 2, 1).reshape(B * KV, Sk, Dv)

    grid = (B * H, Sq // block_q, Sk // block_k)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        b, h = bh // H, bh % H
        return (b * KV + h // G, ki, 0)

    kernel = functools.partial(
        _fwd_kernel, causal=causal, scale=scale, block_q=block_q,
        block_k=block_k, q_offset=q_offset)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, Dk), q_map),
            pl.BlockSpec((1, block_k, Dk), kv_map),
            pl.BlockSpec((1, block_k, Dv), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, Dv), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, STATS_LANES), jnp.float32),
            pltpu.VMEM((block_q, STATS_LANES), jnp.float32),
            pltpu.VMEM((block_q, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return jnp.moveaxis(out.reshape(B, H, Sq, Dv), 1, 2)
