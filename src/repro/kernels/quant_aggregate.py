"""Pallas TPU fused dequantize + weighted-aggregate kernel.

The FL hot loop the paper benchmarks (its Fig. 8e/9e/12b "network bandwidth"
plots) is client-delta aggregation. Communication-efficient FL sends int8
block-quantized deltas; the naive path dequantizes every client to f32 (4x HBM
traffic) before averaging. This kernel fuses dequant + weighted reduce so each
int8 byte is read exactly once and only the f32 result is written.

Layout: deltas (C, N) int8, per-block scales (C, N/block) f32, weights (C,).
Grid over N tiles; the client dim stays resident in VMEM (C <= ~64).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _agg_kernel(qd_ref, sc_ref, w_ref, out_ref, *, qblock: int):
    qd = qd_ref[...]                        # (C, BN) int8
    sc = sc_ref[...]                        # (C, BN // qblock) f32
    w = w_ref[...]                          # (C, 1) f32
    C, BN = qd.shape
    d = qd.astype(jnp.float32).reshape(C, BN // qblock, qblock)
    d = d * sc[:, :, None] * w[:, :, None]
    out_ref[...] = d.sum(axis=0).reshape(BN)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def quant_aggregate(qdeltas, scales, weights, *, block_n: int = 4096,
                    interpret: bool = False):
    """-> (N,) f32: sum_c weights[c] * dequant(qdeltas[c])."""
    C, N = qdeltas.shape
    nblocks = scales.shape[1]
    qblock = N // nblocks
    block_n = min(block_n, N)
    assert N % block_n == 0 and block_n % qblock == 0

    grid = (N // block_n,)
    return pl.pallas_call(
        functools.partial(_agg_kernel, qblock=qblock),
        grid=grid,
        in_specs=[
            pl.BlockSpec((C, block_n), lambda i: (0, i)),
            pl.BlockSpec((C, block_n // qblock), lambda i: (0, i)),
            pl.BlockSpec((C, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), jnp.float32),
        interpret=interpret,
    )(qdeltas, scales, weights.reshape(C, 1))
