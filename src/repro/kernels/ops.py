"""Public kernel API with backend dispatch.

Backends:
- ``pallas``    — the Pallas TPU kernels (production target);
- ``interpret`` — same kernels executed with ``interpret=True`` (CPU-correct);
- ``jnp``       — blockwise pure-jnp implementations with flash-style memory
                  behaviour. This is what the CPU dry-run compiles, so the
                  lowered HLO never materializes an (S x S) score matrix.

Default: ``pallas`` on TPU, ``jnp`` elsewhere; override with env
``REPRO_KERNEL_IMPL``. Training always differentiates through the jnp
blockwise path (flash-style recomputing backward via ``jax.custom_vjp``).
"""
from __future__ import annotations

import contextlib
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as _ref


def backend() -> str:
    impl = os.environ.get("REPRO_KERNEL_IMPL", "auto")
    if impl != "auto":
        return impl
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention in pure jnp — forward
# ---------------------------------------------------------------------------

def _score_dtype():
    """REPRO_BF16_SCORES=1: materialize attention scores/probs in bf16.

    The Pallas TPU kernel computes f32 scores in VMEM — they never touch
    HBM. The jnp blockwise stand-in (CPU dry-run) materializes them, so the
    roofline harness enables this flag to reproduce the KERNEL's HBM traffic
    profile; numerics-sensitive tests run with it off (f32)."""
    return jnp.bfloat16 if os.environ.get("REPRO_BF16_SCORES") == "1" \
        else jnp.float32


def _blockwise_fwd(q, k, v, causal, q_offset, scale, block_q, block_k):
    """Returns (out (B,Sq,H,Dv), lse (B,H,Sq) f32). Memory O(block) not O(S^2)."""
    B, Sq, H, Dk = q.shape
    _, Sk, KVH, Dv = v.shape
    G = H // KVH
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    nq, nk = Sq // block_q, Sk // block_k

    qg = jnp.moveaxis(q.reshape(B, nq, block_q, KVH, G, Dk), 1, 0)
    kc = jnp.moveaxis(k.reshape(B, nk, block_k, KVH, Dk), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nk, block_k, KVH, Dv), 1, 0)

    def per_qblock(qi, qblk):
        q_start = q_offset + qi * block_q

        def kv_step(carry, xs):
            o, m, l = carry
            kb, vb, ks = xs
            s = jnp.einsum("bqkgd,btkd->bkgqt", qblk, kb).astype(_score_dtype())
            s = s * jnp.asarray(scale, s.dtype)
            if causal:
                qpos = q_start + jnp.arange(block_q)
                kpos = ks + jnp.arange(block_k)
                s = jnp.where((qpos[:, None] >= kpos[None, :])[None, None, None],
                              s, jnp.asarray(-1e30, s.dtype))
            s = s.astype(jnp.float32)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None]).astype(_score_dtype())
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.astype(jnp.float32).sum(-1)
            pv = jnp.einsum("bkgqt,btkd->bkgqd", p.astype(vb.dtype), vb)
            o = o * alpha[..., None] + pv.astype(jnp.float32)
            return (o, m_new, l), None

        o0 = jnp.zeros((B, KVH, G, block_q, Dv), jnp.float32)
        m0 = jnp.full((B, KVH, G, block_q), -1e30, jnp.float32)
        l0 = jnp.zeros((B, KVH, G, block_q), jnp.float32)
        ks = jnp.arange(nk) * block_k
        if causal:
            # only scan kv blocks that can intersect the causal triangle
            pass  # masking handles it; block skipping is a pallas-level win
        (o, m, l), _ = jax.lax.scan(kv_step, (o0, m0, l0), (kc, vc, ks))
        o = o / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        out = jnp.moveaxis(o, 3, 1).reshape(B, block_q, KVH * G, Dv)
        return out.astype(q.dtype), lse.reshape(B, H, block_q)

    _, (outs, lses) = jax.lax.scan(
        lambda c, xs: (c, per_qblock(*xs)), 0, (jnp.arange(nq), qg))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, Dv)
    lse = jnp.moveaxis(lses, 0, 2).reshape(B, H, Sq)   # (nq,B,H,bq)->(B,H,Sq)
    return out, lse


# ---------------------------------------------------------------------------
# Flash backward (recomputes p per block pair; saves only out + lse)
# ---------------------------------------------------------------------------

def _blockwise_bwd(q, k, v, out, lse, dout, causal, q_offset, scale,
                   block_q, block_k):
    """Flash backward, KV-outer / Q-inner loop order.

    dk/dv for a kv block are EMITTED per step (scan ys — written once each)
    while only dq (Sq-sized, the small side under sequence sharding) rides
    the carry. The kv-outer order cuts the dominant HBM term ~(Sk/Sq)x vs
    carrying Sk-sized dk/dv accumulators through a q-outer scan
    (EXPERIMENTS.md §Perf iteration 2).
    """
    B, Sq, H, Dk = q.shape
    _, Sk, KVH, Dv = v.shape
    G = H // KVH
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    nq, nk = Sq // block_q, Sk // block_k

    # delta_i = rowsum(dout_i * out_i)
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), -1)
    delta = jnp.moveaxis(delta, 1, 2)                       # (B, H, Sq)

    qg = q.reshape(B, Sq, KVH, G, Dk)
    dog = dout.reshape(B, Sq, KVH, G, Dv)
    lseg = lse.reshape(B, KVH, G, Sq)
    delg = delta.reshape(B, KVH, G, Sq)
    kc = jnp.moveaxis(k.reshape(B, nk, block_k, KVH, Dk), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nk, block_k, KVH, Dv), 1, 0)

    def kv_step(dq_acc, kxs):
        kb, vb, ks = kxs
        s = jnp.einsum("bqkgd,btkd->bkgqt", qg, kb).astype(_score_dtype())
        s = s * jnp.asarray(scale, s.dtype)
        if causal:
            qpos = q_offset + jnp.arange(Sq)
            kpos = ks + jnp.arange(block_k)
            s = jnp.where((qpos[:, None] >= kpos[None, :])[None, None, None],
                          s, jnp.asarray(-1e30, s.dtype))
        p = jnp.exp(s.astype(jnp.float32)
                    - lseg[..., None]).astype(_score_dtype())
        dp = jnp.einsum("bqkgd,btkd->bkgqt", dog, vb).astype(_score_dtype())
        ds = (p.astype(jnp.float32) * (dp.astype(jnp.float32)
                                       - delg[..., None])
              * scale).astype(_score_dtype())
        dqb = jnp.einsum("bkgqt,btkd->bqkgd", ds.astype(kb.dtype), kb)
        dkb = jnp.einsum("bkgqt,bqkgd->btkd", ds.astype(qg.dtype), qg)
        dvb = jnp.einsum("bkgqt,bqkgd->btkd", p.astype(dog.dtype), dog)
        return dq_acc + dqb.astype(jnp.float32), (dkb, dvb)

    dq0 = jnp.zeros((B, Sq, KVH, G, Dk), jnp.float32)
    ks = jnp.arange(nk) * block_k
    dq, (dks, dvs) = jax.lax.scan(kv_step, dq0, (kc, vc, ks))
    dq = dq.reshape(B, Sq, H, Dk).astype(q.dtype)
    dk = jnp.moveaxis(dks, 0, 1).reshape(B, Sk, KVH, Dk).astype(k.dtype)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(B, Sk, KVH, Dv).astype(v.dtype)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# Public flash attention (differentiable, backend-dispatched)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def flash_attention(q, k, v, q_offset=0, causal: bool = True,
                    scale: float | None = None, block_q: int = 512,
                    block_k: int = 512):
    """Differentiable flash attention. q:(B,Sq,H,Dk) k:(B,Sk,KV,Dk) v:(B,Sk,KV,Dv).

    ``q_offset`` — global position of q row 0; may be a traced scalar (e.g.
    ``axis_index('model') * S_loc`` for sequence-sharded attention).
    """
    out, _ = _fa_fwd_rule(q, k, v, q_offset, causal, scale, block_q, block_k)
    return out


def _is_static_int(x) -> bool:
    return isinstance(x, (int, np.integer))


def _fa_fwd_rule(q, k, v, q_offset, causal, scale, block_q, block_k):
    scale = float(scale if scale is not None else 1.0 / np.sqrt(q.shape[-1]))
    impl = backend()
    if impl in ("pallas", "interpret") and _is_static_int(q_offset):
        from repro.kernels.flash_attention import flash_attention_fwd
        out = flash_attention_fwd(
            q, k, v, causal=causal, scale=scale, block_q=block_q,
            block_k=block_k, q_offset=int(q_offset),
            interpret=(impl == "interpret"))
        # lse is recomputed blockwise in the bwd rule when grads are needed
        return out, (q, k, v, q_offset, out, None)
    out, lse = _blockwise_fwd(q, k, v, causal, q_offset, scale,
                              block_q, block_k)
    return out, (q, k, v, q_offset, out, lse)


def _fa_bwd_rule(causal, scale, block_q, block_k, res, dout):
    q, k, v, q_offset, out, lse = res
    scale = float(scale if scale is not None else 1.0 / np.sqrt(q.shape[-1]))
    if lse is None:  # pallas fwd didn't keep lse: recompute blockwise
        out, lse = _blockwise_fwd(q, k, v, causal, q_offset, scale,
                                  block_q, block_k)
    dq, dk, dv = _blockwise_bwd(q, k, v, out, lse, dout, causal, q_offset,
                                scale, block_q, block_k)
    d_off = None if _is_static_int(q_offset) else jnp.zeros_like(q_offset)
    return dq, dk, dv, d_off


flash_attention.defvjp(_fa_fwd_rule, _fa_bwd_rule)


# ---------------------------------------------------------------------------
# Decode attention (not differentiated — serving only)
# ---------------------------------------------------------------------------

def decode_attention(q, k, v, length, *, scale: float | None = None,
                     block_k: int = 512, combine: bool = True):
    """One-token attention over a KV cache; optionally returns (o, m, l) stats."""
    impl = backend()
    if impl in ("pallas", "interpret"):
        from repro.kernels.decode_attention import decode_attention_fwd
        o, m, l = decode_attention_fwd(
            q, k, v, length, scale=scale, block_k=block_k,
            interpret=(impl == "interpret"))
    else:
        o, m, l = _decode_blockwise(q, k, v, length, scale=scale,
                                    block_k=block_k)
    if combine:
        return (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
    return o, m, l


def _decode_blockwise(q, k, v, length, *, scale, block_k):
    """jnp blockwise decode: scans kv chunks; never forms (B,H,S) f32 at once
    beyond one chunk. Returns unnormalized (o, m, l)."""
    B, H, Dk = q.shape
    _, S, KVH, Dv = v.shape
    G = H // KVH
    scale = float(scale if scale is not None else 1.0 / np.sqrt(Dk))
    block_k = min(block_k, S)
    nk = S // block_k
    qg = q.reshape(B, KVH, G, Dk)
    kc = jnp.moveaxis(k.reshape(B, nk, block_k, KVH, Dk), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nk, block_k, KVH, Dv), 1, 0)

    def step(carry, xs):
        o, m, l = carry
        kb, vb, ks = xs
        s = jnp.einsum("bkgd,btkd->bkgt", qg, kb).astype(jnp.float32) * scale
        kpos = ks + jnp.arange(block_k)
        s = jnp.where(kpos[None, None, None] < length[:, None, None, None],
                      s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1)
        o = o * alpha[..., None] + jnp.einsum(
            "bkgt,btkd->bkgd", p.astype(vb.dtype), vb).astype(jnp.float32)
        return (o, m_new, l), None

    o0 = jnp.zeros((B, KVH, G, Dv), jnp.float32)
    m0 = jnp.full((B, KVH, G), -1e30, jnp.float32)
    l0 = jnp.zeros((B, KVH, G), jnp.float32)
    (o, m, l), _ = jax.lax.scan(step, (o0, m0, l0),
                                (kc, vc, jnp.arange(nk) * block_k))
    return o.reshape(B, H, Dv), m.reshape(B, H), l.reshape(B, H)


# ---------------------------------------------------------------------------
# RMSNorm / quantized aggregation
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps: float = 1e-6):
    impl = backend()
    if impl in ("pallas", "interpret"):
        from repro.kernels.rmsnorm import rmsnorm as _k
        return _k(x, w, eps=eps, interpret=(impl == "interpret"))
    return _ref.rmsnorm_ref(x, w, eps)


# ---------------------------------------------------------------------------
# Quantized aggregation (the FL compressed-comms hot path)
# ---------------------------------------------------------------------------

# Trace-time dispatch counters. ``calls`` increments every time a program
# containing quant_aggregate is TRACED (cached jit re-executions do not
# retrace), so tests assert the compressed drivers really route through this
# function — instrumentation, not code inspection.
#
# Counters are SCOPED, not process-global: ``quant_agg_scope()`` pushes a
# fresh frame, increments land on every active frame, and
# ``quant_agg_stats()`` snapshots the innermost one — so two runs in one
# process (each executor's chunk loop holds its own scope) never bleed
# routing counts into each other's telemetry, while the bottom frame keeps
# the legacy process-wide view for callers outside any scope.
def _quant_agg_frame() -> dict:
    return {"calls": 0, "batched_fallbacks": 0, "last_impl": None}


_QUANT_AGG_FRAMES = [_quant_agg_frame()]


def quant_agg_stats() -> dict:
    """Snapshot of the innermost active scope's dispatch counters (the
    process-wide frame when no ``quant_agg_scope`` is open)."""
    return dict(_QUANT_AGG_FRAMES[-1])


def reset_quant_agg_stats() -> None:
    """Zero the innermost active scope's counters."""
    _QUANT_AGG_FRAMES[-1].update(_quant_agg_frame())


@contextlib.contextmanager
def quant_agg_scope():
    """A fresh counter frame for one run's telemetry. Yields the live frame
    dict; increments inside the scope also propagate to every enclosing
    frame (outer totals stay complete)."""
    frame = _quant_agg_frame()
    _QUANT_AGG_FRAMES.append(frame)
    try:
        yield frame
    finally:
        _QUANT_AGG_FRAMES.remove(frame)


def _quant_agg_bump(key: str) -> None:
    for frame in _QUANT_AGG_FRAMES:
        frame[key] += 1


def _quant_agg_impl(name) -> None:
    for frame in _QUANT_AGG_FRAMES:
        frame["last_impl"] = name


def _is_batched(*arrays) -> bool:
    """True when tracing under a jax.vmap (campaign lane axis)."""
    from jax.interpreters import batching
    return any(isinstance(a, batching.BatchTracer) for a in arrays)


def _quant_agg_fused(qdeltas, scales, weights):
    """Fused dequant + weighted sum: the client accumulation is unrolled
    (C is a static shape), so XLA fuses the whole chain into ONE pass over
    the output — each int8 byte is converted in-register and feeds the
    accumulator directly; the (C, N) f32 dequant never exists in memory.
    (A ``.sum(axis=0)`` or einsum formulation defeats this on CPU: XLA
    materializes reduce/dot-general operands.)"""
    C, N = qdeltas.shape
    nblocks = scales.shape[-1]
    out = jnp.zeros((nblocks, N // nblocks), jnp.float32)
    for c in range(C):
        deq = qdeltas[c].astype(jnp.float32).reshape(nblocks, -1) \
            * scales[c, :, None]
        out = out + deq * weights[c]
    return out.reshape(N)


def _quant_agg_dequant_first(qdeltas, scales, weights):
    """Reference path: materialize the whole (C, N) f32 dequant, then run
    the same unrolled weighted accumulation over it. ``optimization_barrier``
    is the identity on values — per-client arithmetic is (q*scale)*weight
    with the identical left-to-right accumulation, so the result is
    bit-for-bit the fused path's — but it pins the f32 intermediate in
    memory: 4x the int8 bytes written AND read back. That traffic gap is
    what BENCH_agg measures and the CI bench gate enforces."""
    C, N = qdeltas.shape
    nblocks = scales.shape[-1]
    d = qdeltas.astype(jnp.float32).reshape(C, nblocks, N // nblocks)
    d = d * scales[..., None]
    d = jax.lax.optimization_barrier(d)
    out = jnp.zeros((nblocks, N // nblocks), jnp.float32)
    for c in range(C):
        out = out + d[c] * weights[c]
    return out.reshape(N)


def _quant_agg_pallas(qdeltas, scales, weights, interpret: bool):
    """Pad-and-mask wrapper around the Pallas kernel: N is padded up to a
    whole number of kernel tiles with zero blocks (q == 0 AND scale == 0, so
    padding contributes exactly 0.0) and the pad lanes are sliced off."""
    from repro.kernels.quant_aggregate import quant_aggregate as _k
    C, N = qdeltas.shape
    qblock = N // scales.shape[-1]
    block_n = qblock * max(1, 4096 // qblock)
    pad = (-N) % block_n
    if pad:
        qdeltas = jnp.pad(qdeltas, ((0, 0), (0, pad)))
        scales = jnp.pad(scales, ((0, 0), (0, pad // qblock)))
    out = _k(qdeltas, scales, weights, block_n=block_n, interpret=interpret)
    return out[:N] if pad else out


def quant_aggregate(qdeltas, scales, weights):
    """-> (N,) f32: ``sum_c weights[c] * dequant(qdeltas[c])``.

    Dispatch (rows: REPRO_KERNEL_IMPL; REPRO_QUANT_AGG=dequant overrides all
    rows with the dequant-first reference path):

    - ``pallas``/``interpret`` — Pallas kernel (compiled / interpret=True),
      via the pad-and-mask wrapper; under a campaign ``vmap`` falls back to
      the fused jnp path with a logged warning (bitwise-identical result);
    - ``jnp`` (CPU default)   — the fused jnp expression.
    """
    mode = os.environ.get("REPRO_QUANT_AGG", "fused")
    if mode not in ("fused", "dequant"):
        raise ValueError(f"REPRO_QUANT_AGG={mode!r} (want fused|dequant)")
    _quant_agg_bump("calls")
    if mode == "dequant":
        _quant_agg_impl("dequant-first")
        return _quant_agg_dequant_first(qdeltas, scales, weights)
    impl = backend()
    if impl in ("pallas", "interpret"):
        if _is_batched(qdeltas, scales, weights):
            import warnings
            _quant_agg_bump("batched_fallbacks")
            _quant_agg_impl("jnp-fused(vmap-fallback)")
            warnings.warn(
                "quant_aggregate: Pallas kernel requested under a vmapped "
                "lane axis; using the fused jnp path for this trace "
                "(bitwise-identical result)", stacklevel=2)
            return _quant_agg_fused(qdeltas, scales, weights)
        _quant_agg_impl(impl)
        return _quant_agg_pallas(qdeltas, scales, weights,
                                 interpret=(impl == "interpret"))
    _quant_agg_impl("jnp-fused")
    return _quant_agg_fused(qdeltas, scales, weights)


def quantize_blockwise(x, block: int = 256):
    return _ref.quantize_blockwise_ref(x, block=block)
